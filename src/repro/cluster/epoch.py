"""The closed-loop epoch driver (paper §5.1 made to actually run).

One *epoch* = one device-side batch step; one *control period* =
``period`` consecutive epochs between controller pulls.  The device step
is a single fused, jitted program —

    inject workload slice
    -> route (counter + load-register + count-min sketch updates)
    -> apply to the store (``apply_routed``, or ``make_dist_apply`` on a
       mesh backend)
    -> build the DES hop plan

— and the host side closes the loop: pull the statistics report, run the
balancing policy, execute the migration plan, graft the refreshed
control tables back onto the live directory (``Controller.refresh`` —
counters survive; ``stats.pull_report`` is the only reset path), and
time the period's traffic on the PR-1 vectorized DES engine
(:mod:`repro.core.des`).

**Device-resident period pipeline** (the default, ``fused=True``): the
whole control period runs as ONE jitted ``lax.scan`` over the period's
pre-staged query batches, with the store slabs, load registers, sketch
and the replication version/dirty register file
(:mod:`repro.replication`) **donated** into the call (the slabs are the
big allocation; no
second live copy exists during the scan; the directory is deliberately
NOT donated — its freshly-grafted zeroed counter tables can alias one
constant buffer, which XLA rejects as a double donation, and it is tiny
next to the slabs).  Per-epoch
observables (hop plans, per-node ops, retries, overflow totals) come
back as stacked device arrays, so the host syncs **once per period**
instead of once per epoch: one batched DES engine call over the stacked
(P, B, H) plans (``stack_plans`` semantics, see
``des.simulate_closed_loop``), percentiles and imbalance vectorized over
the period.  NetCache/DistCache-style designs work precisely because
the data plane runs many intervals between control-plane pulls; so does
this driver.

The fused driver is **observationally equivalent** to per-epoch stepping
(``fused=False``): policies only ever act on period-boundary reports, so
fusing the epochs between two pulls changes no policy input, and the
``run()``/:class:`EpochMetrics` stream and final store state are
bit-identical — asserted in ``tests/test_epoch_fused.py``.  Scenario
control events (fail/recover/rack_fail) only ever fire at epoch
boundaries; a segment simply ends early at the next event epoch, and the
scan's fixed length is padded with masked (no-op) epochs so the program
still compiles exactly once per scenario.

Shape discipline: scenario batches, directory tables, the sketch, and
the load registers all keep fixed shapes across control updates (chain
widening only rewrites ``chain_len`` values; hot-subset splits allocate
pre-reserved directory slots — ``make_directory(r_max=, n_slots=)``
reserves both kinds of headroom), so the period scan traces **once per
scenario** — asserted via :attr:`EpochDriver.traces` (the jit cache
size, which also catches dist-backend retraces) in tests and recorded
per bench row.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import core as C
from repro.core import directory as D
from repro.core import keys as K
from repro.core import routing as R
from repro.core.controller import Controller, ControllerConfig
from repro.core.coordination import LatencyModel, plan_hops
from repro.core.dist_store import (
    DistConfig,
    make_dist_apply,
    make_dist_period,
)
from repro.core.migration import execute as execute_migrations
from repro.core.stats import make_sketch, pull_report, sketch_query, sketch_update
from repro.core.store import apply_routed, make_store
from repro import coordination_tier as CT
from repro import overload as OVL
from repro import replication as RPL
from repro import telemetry as TEL
from repro.telemetry import metrics as MTR
from repro.telemetry import slo as SLOM

from repro.cluster.metrics import (
    EpochMetrics,
    imbalance_stats_batch,
    latency_percentiles_batch,
    masked_p99_batch,
    migration_traffic,
    p999_batch,
)
from repro.cluster.policies import Policy
from repro.cluster.scenarios import Scenario


@dataclasses.dataclass
class ClusterConfig:
    """Cluster geometry + timing knobs for a driver run."""

    num_nodes: int = 8
    num_ranges: int = 64
    replication: int = 2
    r_max: int = 4                 # chain-slot headroom for widening
    # range-slot pool size; None -> 2x num_ranges (headroom for hot-subset
    # splits, the slot-pool analogue of the r_max chain headroom)
    n_slots: int | None = None
    capacity: int | None = None    # per-shard slots; None -> sized from scenario
    mode: str = C.IN_SWITCH
    n_clients: int = 32            # DES closed-loop client count
    # consistency mode over the replica chains (repro.replication):
    # "eventual" (pre-subsystem behaviour, bit-identical), "chain"
    # (CR: tail reads, full-chain writes) or "craq" (apportioned reads
    # with dirty-bit tail bounces)
    replication_mode: str = "eventual"
    # epochs per controller pull == the fused scan's period length;
    # None -> the policy's declared ``pull_every`` cadence; "auto" ->
    # adaptive cadence: the next period is picked from report-to-report
    # load drift inside ``auto_band`` (the fused scan is sized at the
    # band maximum and shorter periods run as masked-padded segments,
    # so the program still compiles once)
    report_every: int | str | None = None
    auto_band: tuple = (1, 8)
    auto_drift_lo: float = 0.1     # drift below this doubles the period
    auto_drift_hi: float = 0.4     # drift above this halves it
    sketch_width: int = 512
    sketch_depth: int = 4
    # distinct-key window cap for the sketch pull view; uniform thinning
    # beyond this (the split policies' quantile consumers are robust to it)
    key_window_cap: int = 1 << 16
    latency: LatencyModel = dataclasses.field(default_factory=LatencyModel)
    # per-hop service-time distribution (fixed | lognormal | pareto)
    service_model: C.ServiceModel = dataclasses.field(
        default_factory=C.ServiceModel
    )
    # intra-epoch p2c freshness: route the batch in this many sub-chunks
    # with load-register updates between them (oracle backend, spread
    # policies; still one compiled step — the chunk loop unrolls)
    p2c_chunks: int = 1
    des_backend: str | None = None
    max_scan_results: int = 8
    imbalance_threshold: float = 1.3   # Controller.balance trigger
    max_moves_per_round: int = 4
    # the overload plane (repro.overload): None disables it and the run
    # is bit-identical to pre-overload behaviour; an OverloadConfig
    # carries bounded per-node admission queues + retry-storm dynamics
    # through the device step (donated through the fused scan)
    overload: OVL.OverloadConfig | None = None
    # capacity-autoscale reserve: nodes parked into Controller.standby
    # at init (before the preload, so they never hold data); the
    # backpressure policies activate/park them as utilization crosses
    # their bands
    standby_nodes: tuple = ()
    # capacity-driven splitting in the loop: at each control pull, split
    # the hottest range headed at any node whose store overflowed since
    # the last pull (Controller.split_overflowed) and — when the slot
    # pool is exhausted — grow the pool and recompile (oracle rebuilds
    # its step; the dist programs re-specialize on the grown shapes by
    # themselves; `traces` then counts 1 + growth_events either way)
    split_overflow: bool = False
    # the trace plane (repro.telemetry): None disables it and the run is
    # bit-identical to pre-telemetry behaviour; a TelemetryConfig samples
    # per-query spans inside the device step (hash-based, no PRNG
    # consumed — the metric stream is bit-identical with tracing on OR
    # off), decomposes tail latency exactly, and times pipeline stages
    telemetry: TEL.TelemetryConfig | None = None
    # the coordination tier (repro.coordination_tier): None disables it
    # and the run is bit-identical to pre-tier behaviour; a CoordConfig
    # replicates the directory onto per-switch table copies that lag the
    # controller's commits along the switch chain, resolving stale routes
    # with versioned redirects.  Accounting plane: store effects, counters
    # and PRNG draws always follow the TRUE routing decision, so a
    # zero-lag tier is also bit-identical to None
    coordination: CT.CoordConfig | None = None
    # the fleet metrics plane (repro.telemetry.metrics): None disables it
    # and the run is bit-identical to pre-metrics behaviour; a
    # MetricsConfig carries a fixed-shape (window, n_series) time-series
    # ring through the device step (donated through the fused scan, like
    # the overload/coordination registers), with SLO burn-rate alerting
    # evaluated on-device at each segment boundary.  Pure observer: no
    # PRNG consumed, no store/counter effects — the EpochMetrics stream
    # is bit-identical with the ring on OR off
    metrics: MTR.MetricsConfig | None = None
    # hashed per-key CRAQ dirty filter width (repro.replication): a craq
    # replica bounces only reads whose key *collides* with an uncommitted
    # write instead of every read of a dirty range.  0 (the default)
    # keeps slot-granular bouncing bit-identically; oracle backend only
    craq_filter_bits: int = 0
    seed: int = 0


def _node_ops(decision: C.RoutingDecision, opcode: jnp.ndarray, num_nodes: int
              ) -> jnp.ndarray:
    """(N,) ops served per node this epoch: reads at their routed target,
    writes at every live chain member (same units as directory.node_load)."""
    is_write = (opcode == K.OP_PUT) | (opcode == K.OP_DEL)
    r_max = decision.chain.shape[1]
    live = (jnp.arange(r_max)[None, :] < decision.chain_len[:, None]) & (
        decision.chain != D.NO_NODE
    )
    w_hit = live & is_write[:, None]
    ops = jnp.zeros((num_nodes,), jnp.int32)
    ops = ops.at[jnp.where(w_hit, decision.chain, 0).reshape(-1)].add(
        w_hit.reshape(-1).astype(jnp.int32)
    )
    # mode="drop": reads against a fully-spliced chain (target NO_NODE)
    # are unserved and must not show up as phantom load on node 0
    ops = ops.at[decision.target].add(
        jnp.where(is_write, 0, 1).astype(jnp.int32), mode="drop"
    )
    return ops


def _merge_unique(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Merge two sorted-unique uint32 arrays in linear time (no re-sort of
    the accumulated window — the incremental key-window dedupe)."""
    if a.size == 0:
        return b
    if b.size == 0:
        return a
    pos = np.searchsorted(a, b)
    hit = (pos < a.size) & (a[np.minimum(pos, a.size - 1)] == b)
    fresh = b[~hit]
    if fresh.size == 0:
        return a
    out = np.empty(a.size + fresh.size, a.dtype)
    at_b = np.searchsorted(a, fresh) + np.arange(fresh.size)
    mask = np.zeros(out.size, bool)
    mask[at_b] = True
    out[mask] = fresh
    out[~mask] = a
    return out


def _jit_cache_size(fn, default: int = 0) -> int:
    cs = getattr(fn, "_cache_size", None)
    return cs() if callable(cs) else default


class EpochDriver:
    """Run a scenario under a policy, one control period at a time.

    ``backend='oracle'`` (default) uses the single-program
    ``apply_routed`` path; ``backend='dist'`` shards the store over a
    mesh axis and goes through ``make_dist_apply`` (the bounded-bucket
    all_to_all data plane) — pass ``mesh``.

    ``fused=True`` (default) runs each control period as one donated
    ``lax.scan`` (oracle) or one deferred-sync step loop (dist) with a
    single host round-trip per period; ``fused=False`` is the per-epoch
    reference loop the fused pipeline is asserted bit-identical against.
    """

    def __init__(
        self,
        scenario: Scenario,
        policy: Policy,
        cfg: ClusterConfig | None = None,
        *,
        backend: str = "oracle",
        mesh=None,
        dist_cfg: DistConfig | None = None,
        fused: bool = True,
    ):
        self.scenario = scenario
        self.policy = policy
        self.cfg = cfg = cfg or ClusterConfig()
        if backend not in ("oracle", "dist"):
            raise ValueError(f"unknown backend {backend!r}")
        if backend == "dist" and mesh is None:
            raise ValueError("backend='dist' needs a mesh")
        self.backend = backend
        self.fused = fused
        # consistency mode wiring: how routing / hop planning / the
        # version register file behave (repro.replication.resolve_mode)
        self.mode_plan = RPL.resolve_mode(
            cfg.replication_mode, policy.read_spread, cfg.replication
        )
        # pull cadence: explicit config wins, else the policy declares it.
        # "auto" picks each period from report-to-report load drift within
        # cfg.auto_band; the fused scan is sized at the band maximum.
        pe = (cfg.report_every if cfg.report_every is not None
              else policy.pull_every)
        self.period_history: list[int] = []
        if pe == "auto":
            lo, hi = int(cfg.auto_band[0]), int(cfg.auto_band[1])
            if not (1 <= lo <= hi):
                raise ValueError(f"bad auto_band {cfg.auto_band}")
            self.auto_period = True
            self.period = hi               # scan length = band maximum
            self._cur_period = lo          # start controlling tightly
            self._next_pull = lo
            self._prev_load: np.ndarray | None = None
            self._last_pull_epoch = 0
            # spread modes: load registers are halved (not reset) at each
            # pull, so drift must difference out the post-halving floor
            # or a decayed tail of prior periods pollutes the signal
            self._reg_floor = np.zeros((cfg.num_nodes,), np.float64)
        else:
            self.auto_period = False
            self.period = int(pe)

        scfg = scenario.cfg
        # keep the policy's notion of base replication honest
        policy.config.base_replication = cfg.replication
        if cfg.p2c_chunks > 1 and scfg.epoch_ops % cfg.p2c_chunks != 0:
            raise ValueError(
                f"epoch_ops {scfg.epoch_ops} not divisible by "
                f"p2c_chunks {cfg.p2c_chunks}"
            )

        n_slots = 2 * cfg.num_ranges if cfg.n_slots is None else cfg.n_slots
        directory = C.make_directory(
            cfg.num_ranges, cfg.num_nodes, cfg.replication, r_max=cfg.r_max,
            n_slots=n_slots,
        )
        self.controller = Controller(
            directory,
            ControllerConfig(
                imbalance_threshold=cfg.imbalance_threshold,
                max_moves_per_round=cfg.max_moves_per_round,
            ),
        )
        # capacity autoscale: park the configured reserve BEFORE the
        # preload, so standby nodes never hold data (the drain is free on
        # an empty store) and the YCSB load phase routes around them
        if cfg.standby_nodes:
            for node in cfg.standby_nodes:
                self.controller.park_node(int(node))
            directory = self.controller.directory()
            # fresh register file below: the park resets are no-ops on it
            self.controller.drain_repl_log()
        capacity = cfg.capacity
        if capacity is None:
            # every record on up to r_max chains, plus 2x headroom for skewed
            # placement and widen copies
            capacity = max(256, 2 * scfg.n_records * cfg.r_max // cfg.num_nodes)
        self.store = make_store(cfg.num_nodes, capacity, scfg.value_dim)
        self.directory = directory
        self.load_reg = jnp.zeros((cfg.num_nodes,), jnp.uint32)
        self.sketch = make_sketch(cfg.sketch_width, cfg.sketch_depth)
        if backend == "dist" and cfg.craq_filter_bits:
            raise ValueError(
                "craq_filter_bits is an oracle-backend measurement "
                "feature; the dist data plane keeps slot-granular "
                "bouncing"
            )
        # the (n_slots, r_max) version/dirty register file, device-resident
        # next to the load registers; carried (and donated) through the
        # fused period scan for chain/craq, inert zeros under eventual
        self.repl = RPL.make_state(n_slots, cfg.r_max, cfg.craq_filter_bits)
        # the coordination tier: per-switch replicated table copies +
        # version registers, carried (and donated) through the fused
        # scan; the host-side CoordManager stages control writes along
        # the switch chain between segments.  None == empty pytree slot,
        # same discipline as the overload plane
        self.coord_cfg = cfg.coordination
        if self.coord_cfg is not None:
            self.coord_mgr = CT.CoordManager(
                self.coord_cfg, self.controller.table_snapshot(),
                num_nodes=cfg.num_nodes,
            )
            self.coord = self.coord_mgr.make_state()
        else:
            self.coord_mgr = None
            self.coord = None
        # previous period's redirect share (redirected / routed) — the
        # policy-facing convergence signal behind redirect_backoff
        self._last_redirect_share = 0.0
        # the overload plane: device-resident per-node queue/retry
        # registers, carried (and donated) through the fused scan; None
        # when disabled — an empty pytree slot, so the step signatures
        # stay uniform and the disabled path compiles the same program
        # as before the subsystem existed
        self.ovl_cfg = cfg.overload
        # the orbit-identity register (cross-epoch retry linking) sizes
        # off the trace plane's knob but lives with the retry orbit it
        # identifies — 0 bits keeps the (1,) placeholder leaf
        _lb = (cfg.telemetry.link_retries
               if cfg.telemetry is not None else 0)
        self.ovl = (OVL.make_state(cfg.num_nodes, cfg.overload,
                                   link_bits=_lb)
                    if cfg.overload is not None else None)
        # the trace plane: spans are assembled inside the device step (no
        # extra sync — they ride the one period round-trip), attributed
        # and archived by the host-side recorder.  None compiles the
        # identical program and produces the identical metric stream.
        self.tel_cfg = cfg.telemetry
        if self.tel_cfg is not None:
            self._tel_threshold = TEL.rate_threshold(
                self.tel_cfg.sample_rate
            )
            self.telemetry = TEL.TelemetryRecorder(
                self.tel_cfg, model=cfg.latency, scenario=scenario.name,
                policy=policy.name, n_clients=cfg.n_clients,
            )
            self._timers = self.telemetry.timers
        else:
            self._tel_threshold = 0
            self.telemetry = None
            self._timers = TEL.StageTimers(enabled=False)
        # the fleet metrics plane: a (window, n_series) f32 ring carried
        # (and donated) through the fused scan; None == empty pytree
        # slot, the same discipline as the overload/coordination planes
        self.met_cfg = cfg.metrics
        self._met_pos = 0   # host mirror of metrics.pos (fold positions)
        if self.met_cfg is not None:
            n_sw = (self.coord_mgr.n_switches
                    if self.coord_mgr is not None else 0)
            self.met_layout = MTR.build_layout(
                cfg.num_nodes, n_switches=n_sw,
                topk=min(self.met_cfg.topk, n_slots),
            )
            for s in self.met_cfg.slos:
                if s.series not in self.met_layout.index:
                    raise ValueError(
                        f"SLO {s.name!r} names unknown series "
                        f"{s.series!r}"
                    )
                need = s.slow_window + self.period
                if self.met_cfg.window < need:
                    raise ValueError(
                        f"metrics window {self.met_cfg.window} too "
                        f"short for SLO {s.name!r}: needs >= "
                        f"slow_window + period = {need} epochs of "
                        "retained history"
                    )
            self.metrics = MTR.make_state(
                self.met_cfg.window, self.met_layout.n_series
            )
            self.met_engine = SLOM.AlertEngine(
                self.met_cfg.slos, on_fire=self._on_slo_fire
            )
        else:
            self.met_layout = None
            self.metrics = None
            self.met_engine = None
        self.key = jax.random.PRNGKey(cfg.seed)

        self._traces = 0
        # compile counts carried across split_overflow step rebuilds: the
        # old program's jit cache size is banked here, so `traces` stays
        # exactly 1 + growth_events when recompiles only follow growth
        self._trace_base = 0
        self.growth_events = 0
        self._period = 0
        self._last_overflow = 0
        self.host_syncs = 0        # device->host round-trips (profile metric)
        # distinct keys seen since the last pull, deduped incrementally
        # (sorted-unique merge per epoch — pull cost no longer grows with
        # epoch_ops x period): queried against the count-min sketch at pull
        # time (StatsReport.key_sample/key_heat, the split policies'
        # boundary-quantile view)
        self._key_window: np.ndarray = np.empty(0, np.uint32)
        # scenario control events are deterministic: precompute the epochs
        # that force a host intervention (segment boundaries for the scan)
        self._event_epochs = {
            e for e in range(scfg.n_epochs) if scenario.events(e)
        }
        self._mesh = mesh
        self._step = None
        self._period_fn = None
        if backend == "dist":
            base = dist_cfg or DistConfig()
            self._dist_cfg = dataclasses.replace(
                base,
                read_spread=self.mode_plan.spread,
                return_decision=True,
                replication_mode=cfg.replication_mode,
                max_scan_results=cfg.max_scan_results,
                queue_pen=(cfg.overload is not None
                           and cfg.overload.queue_weight > 0
                           and self.mode_plan.spread),
            )
            if fused:
                # the whole period inside ONE shard_map (a2a rounds in
                # the scan body) — compiled once, like the oracle scan
                self._dist_apply = None
                self._period_fn = self._build_dist_period()
            else:
                self._dist_apply = make_dist_apply(
                    mesh, directory, self._dist_cfg
                )
                self._step = self._build_dist_step()
        elif fused:
            self._period_fn = self._build_oracle_period(self.mode_plan)
        else:
            self._step = self._build_oracle_step(self.mode_plan)

        self._preload()

    # -- properties --------------------------------------------------------
    @property
    def traces(self) -> int:
        """How many distinct programs the epoch/period device step has
        compiled (the no-retracing acceptance gate: must be 1 after any
        number of epochs of one scenario).

        Counted from the jit compile-cache size wherever one exists — the
        python-side-effect counter under-reports a ``lax.scan`` body
        (traced more than once inside a single compile) and cannot see a
        dist-backend retrace at all, because ``make_dist_apply`` keys its
        own jit cache on input shardings.  Both caches are folded in so
        neither path can hide a retrace behind the other's count."""
        if self.backend == "oracle":
            if self.fused:
                return self._trace_base + _jit_cache_size(
                    self._period_fn, self._traces
                )
            return max(self._traces,
                       self._trace_base + _jit_cache_size(self._step, 0))
        t = self._traces
        if self.fused:
            # the fused dist period program: one cache entry per distinct
            # shape set (pool growth retraces it, counted like the oracle)
            return max(t, _jit_cache_size(self._dist_period, 0))
        return max(t, _jit_cache_size(self._dist_apply, 0))

    # -- setup -------------------------------------------------------------
    def _preload(self):
        """YCSB load phase: PUT every record through the normal data path."""
        keys, vals = self.scenario.load()
        q = C.make_queries(
            jnp.asarray(keys),
            jnp.full((len(keys),), K.OP_PUT),
            jnp.asarray(vals),
        )
        decision, _ = R.route(self.directory, q)  # discard counter bumps
        self.store, _ = apply_routed(
            self.store, q, decision, max_scan_results=self.cfg.max_scan_results
        )
        ovf = np.asarray(self.store.overflow).astype(np.int64)
        self._last_overflow = int(ovf.sum())
        # per-node overflow floor for capacity-driven splitting (which
        # node's store pushed past capacity since the last control pull)
        self._ovf_node_last = ovf

    # -- device step variants ----------------------------------------------
    def _make_oracle_body(self, mp: RPL.ModePlan):
        """One epoch's device math — shared verbatim by the per-epoch jit
        and the fused period scan so the two are the same program.

        ``mp`` wires the replication mode: p2c read spreading on or off,
        CRAQ dirty-bit tail bounces, the write path's client-visible
        chain cap, and whether the version register file advances."""
        cfg = self.cfg
        N = cfg.num_nodes
        spread = mp.spread
        # eventual mode under a spreading policy: widened members are
        # lazily-refreshed read replicas, the write's client-visible path
        # is the base chain only.  chain/craq broadcast down the whole
        # chain (see plan_hops / repro.replication.protocol).
        cap = mp.write_cap_spread
        # intra-epoch p2c freshness: sub-chunk the batch so the load
        # registers the p2c rule reads are at most 1/chunks of an epoch
        # stale.  The chunk loop unrolls inside the single jitted step —
        # the trace count stays 1.
        chunks = cfg.p2c_chunks if spread else 1
        # the overload plane (trace constants; None leaves every value
        # computed below bit-identical to the pre-overload program)
        ocfg = self.ovl_cfg
        # the trace plane (also trace constants; sampling consumes no
        # PRNG, so even the *enabled* path leaves every pre-existing
        # value bit-identical — only the extra span outputs are new)
        tcfg = self.tel_cfg
        tel_thr = self._tel_threshold
        # the coordination tier (trace constants; observe_epoch consumes
        # no PRNG and touches no store/counter state, so the disabled and
        # zero-lag paths are bit-identical — only the redirect pricing and
        # the new cstats output differ when the tables actually diverge)
        ccfg = self.coord_cfg
        hp = bool(getattr(self.directory, "hash_partitioned", False))
        fbits = cfg.craq_filter_bits
        # the metrics plane (trace constants; record_epoch consumes no
        # PRNG and the None path compiles the identical program)
        mcfg = self.met_cfg
        met_topk = self.met_layout.topk if mcfg is not None else 0

        def route_chunk(directory, load_reg, dirty, kf, qs, rng_c,
                        queue_pen):
            if mp.dirty_reads:
                dec, directory, load_reg, picked, bounced = (
                    R.route_load_aware_dirty(directory, qs, load_reg, dirty,
                                             rng_c, queue_pen=queue_pen,
                                             key_filter=kf)
                )
            elif spread:
                dec, directory, load_reg = R.route_load_aware(
                    directory, qs, load_reg, rng_c, queue_pen=queue_pen
                )
                picked = bounced = None
            else:
                dec, directory = R.route(directory, qs)
                picked = bounced = None
            return dec, directory, load_reg, picked, bounced

        def body(store, directory, load_reg, sketch, repl, ovl, coord,
                 metrics, q, rng, eid):
            if ocfg is not None:
                # fold_in (not a wider split) so the disabled path's
                # r_route/r_plan streams are untouched — routing and the
                # hop-plan service draws stay bit-identical either way
                r_ovl = jax.random.fold_in(rng, 0x0F10AD)
            r_route, r_plan = jax.random.split(rng)
            B = q.opcode.shape[0]
            # deep queues repel p2c reads: the pre-epoch queue depth joins
            # the load registers in the pick comparison (registers still
            # bump raw, and the kernels fold the same penalty at the ops
            # layer — parity by construction)
            queue_pen = None
            if ocfg is not None and ocfg.queue_weight > 0 and spread:
                queue_pen = ovl.queue.astype(jnp.uint32) * jnp.uint32(
                    ocfg.queue_weight
                )
            # reads consult the PRE-epoch dirty state, exactly as they
            # observe the pre-batch store (repro.replication.state)
            dirty = RPL.dirty_bits(repl) if mp.dirty_reads else None
            kf = (repl.key_filter
                  if (mp.dirty_reads and fbits) else None)
            if spread and chunks > 1:
                csize = B // chunks
                decs, picks, bncs = [], [], []
                for ci in range(chunks):
                    qs = jax.tree.map(
                        lambda x: x[ci * csize : (ci + 1) * csize], q
                    )
                    dec, directory, load_reg, picked, bounced = route_chunk(
                        directory, load_reg, dirty, kf, qs,
                        jax.random.fold_in(r_route, ci), queue_pen,
                    )
                    decs.append(dec)
                    picks.append(picked)
                    bncs.append(bounced)
                decision = jax.tree.map(
                    lambda *xs: jnp.concatenate(xs, axis=0), *decs
                )
                if mp.dirty_reads:
                    picked = jnp.concatenate(picks, axis=0)
                    bounced = jnp.concatenate(bncs, axis=0)
            else:
                decision, directory, load_reg, picked, bounced = route_chunk(
                    directory, load_reg, dirty, kf, q, r_route, queue_pen
                )
            node_ops = _node_ops(decision, q.opcode, N)
            if not spread:
                # tail-read path: registers tracked for parity (same units)
                load_reg = load_reg + node_ops.astype(jnp.uint32)
            sketch = sketch_update(sketch, q.key)
            store, resp = apply_routed(
                store, q, decision, max_scan_results=cfg.max_scan_results
            )
            bounce_kw = (
                dict(read_via=picked, read_bounce=bounced)
                if mp.dirty_reads else {}
            )
            # overload step: queue/retry dynamics decide each query's
            # timing fate (the store above applied every op regardless —
            # accounting plane, see repro.overload).  The pre-step state
            # is the admission context the trace plane records: queue
            # depth at entry, exactly as routing observes the pre-epoch
            # store
            ovl_pre = ovl
            if ocfg is not None:
                ovl, ovl_rej, ovl_scale, ovl_out, ostats = OVL.step(
                    ovl, decision.target, r_ovl, ocfg
                )
                ovl_kw = dict(shed=ovl_rej, service_scale=ovl_scale)
                # cross-epoch retry linking: stamp/clear the hashed
                # orbit-identity register (no-op at the (1,) placeholder)
                ovl, first_epoch = OVL.link_orbit(
                    ovl, q.key, ovl_rej,
                    ovl_out == OVL.OUTCOME_ADMITTED, eid,
                )
            else:
                ostats = jnp.zeros((len(OVL.STAT_FIELDS),), jnp.int32)
                ovl_kw = {}
                first_epoch = None
            # the switch tier observes the batch against its (possibly
            # stale) per-switch table copies: versioned-redirect decision,
            # install of pending control writes, conservation counters.
            # Pure accounting — the decision above (and every store/
            # counter/PRNG effect) followed the TRUE tables, so the tier
            # only reprices hops and emits cstats
            if ccfg is not None:
                coord, redirect, redirect_via, cstats = CT.observe_epoch(
                    coord, q, decision, eid, quorum=ccfg.quorum,
                    hash_partitioned=hp,
                )
                coord_kw = dict(redirect=redirect,
                                redirect_via=redirect_via)
            else:
                redirect = None
                cstats = CT.empty_cstats()
                coord_kw = {}
            plan = plan_hops(
                q, decision, cfg.mode, cfg.latency, rng=r_plan, num_nodes=N,
                write_chain_cap=cap, service_model=cfg.service_model,
                **bounce_kw, **ovl_kw, **coord_kw,
            )
            if mp.track_state:
                is_write = (q.opcode == K.OP_PUT) | (q.opcode == K.OP_DEL)
                repl = RPL.advance(repl, decision.ridx, is_write,
                                   keys=q.key if fbits else None)
            retries = jnp.zeros((), jnp.int32)
            bounced_out = (bounced if mp.dirty_reads
                           else jnp.zeros((B,), jnp.bool_))
            # span attribution only: a versioned redirect rides the bounce
            # bucket of the trace plane (an extra pre-serve hop), while the
            # metric-stream bounced column stays CRAQ-only for parity
            span_bounced = (bounced_out if redirect is None
                            else bounced_out | redirect)
            if tcfg is not None:
                if ocfg is not None:
                    t_safe = jnp.clip(decision.target, 0, N - 1)
                    qdepth = ovl_pre.queue[t_safe]
                    Lv = ovl_pre.retry.shape[1]
                    # deepest occupied backoff level at the target (how
                    # far its retry orbit has escalated); -1 when empty
                    orbit_node = jnp.max(
                        jnp.where(
                            ovl_pre.retry > 0,
                            jnp.arange(1, Lv + 1, dtype=jnp.int32)[None, :],
                            0,
                        ),
                        axis=1,
                    ) - 1
                    orbit = orbit_node[t_safe]
                    outcome = ovl_out
                    scale_rec = ovl_scale
                else:
                    qdepth = jnp.zeros((B,), jnp.int32)
                    orbit = jnp.full((B,), -1, jnp.int32)
                    outcome = jnp.where(
                        decision.target >= 0,
                        jnp.int32(OVL.OUTCOME_ADMITTED),
                        jnp.int32(OVL.OUTCOME_INVALID),
                    )
                    scale_rec = jnp.ones((B,), jnp.float32)
                pk = picked if mp.dirty_reads else decision.target
                spans = TEL.collect_spans(
                    q, eid, decision, pk, span_bounced, outcome, qdepth,
                    orbit, scale_rec, plan,
                    threshold=tel_thr, k_slots=tcfg.max_spans,
                    lookup=cfg.latency.lookup, first_epoch=first_epoch,
                )
            else:
                spans = None
            if mcfg is not None:
                # the fleet metrics row: post-step ovl, post-observe
                # coord, post-advance repl — end-of-epoch state, like
                # the flight ring's snapshots.  Pure observer.
                metrics = MTR.record_epoch(
                    metrics, node_ops=node_ops, ovl=ovl, ostats=ostats,
                    cstats=cstats, coord=coord, repl=repl, sketch=sketch,
                    keys=q.key, ridx=decision.ridx, topk=met_topk,
                )
            return (store, directory, load_reg, sketch, repl, ovl, coord,
                    metrics, plan, node_ops, retries, bounced_out, ostats,
                    cstats, spans)

        return body

    def _build_oracle_step(self, mp: RPL.ModePlan):
        body = self._make_oracle_body(mp)

        def step(store, directory, load_reg, sketch, repl, ovl, coord,
                 metrics, q, rng, eid):
            self._traces += 1  # python side effect: counts traces, not calls
            return body(store, directory, load_reg, sketch, repl, ovl,
                        coord, metrics, q, rng, eid)

        return jax.jit(step)

    def _build_oracle_period(self, mp: RPL.ModePlan):
        """The fused period program: ``period`` epoch bodies under one
        jitted ``lax.scan`` with the store/directory/load-register/sketch
        buffers **donated** (the store slabs are the big allocation — the
        scan updates them in place, no second live copy).

        Dead scan slots (segments cut short by a control event or the run
        end) compute but do not commit: the carry keeps its pre-step value
        and the host discards their output rows, so one fixed-length
        program covers every segment length — exactly one trace per
        scenario."""
        body = self._make_oracle_body(mp)

        def period(store, directory, load_reg, sketch, repl, ovl, coord,
                   metrics, qs, rngs, live, eids):
            def scan_body(carry, xs):
                (store, directory, load_reg, sketch, repl, ovl, coord,
                 metrics) = carry
                q, rng, lv, eid = xs
                (store2, directory2, load_reg2, sketch2, repl2, ovl2,
                 coord2, metrics2, plan, node_ops, retries, bounced,
                 ostats, cstats, spans) = body(
                    store, directory, load_reg, sketch, repl, ovl, coord,
                    metrics, q, rng, eid
                )
                keep = lambda new, old: jnp.where(lv, new, old)
                store2 = jax.tree.map(keep, store2, store)
                directory2 = jax.tree.map(keep, directory2, directory)
                carry2 = (store2, directory2, keep(load_reg2, load_reg),
                          keep(sketch2, sketch),
                          jax.tree.map(keep, repl2, repl),
                          jax.tree.map(keep, ovl2, ovl),
                          jax.tree.map(keep, coord2, coord),
                          jax.tree.map(keep, metrics2, metrics))
                ovf = jnp.sum(store2.overflow)
                # spans ride the ys stack (None == empty pytree when the
                # trace plane is off — the program is unchanged)
                return carry2, (plan, node_ops, retries, ovf, bounced,
                                ostats, cstats, spans)

            carry, outs = jax.lax.scan(
                scan_body,
                (store, directory, load_reg, sketch, repl, ovl, coord,
                 metrics),
                (qs, rngs, live, eids),
            )
            return (*carry, *outs)

        # donate the big buffers: store slabs, load registers, sketch, the
        # replication register file (version/dirty tables), the overload
        # queue/retry registers, the coordination tier's per-switch
        # table copies and the metrics ring (each an empty pytree when
        # disabled — donating one is then a no-op).
        # The directory is NOT donated — several of its freshly-grafted
        # tables (e.g. the zeroed read/write counters) can alias the same
        # constant buffer, which XLA rejects as a double donation; it is
        # also tiny next to the slabs, so nothing is lost.
        return jax.jit(period, donate_argnums=(0, 2, 3, 4, 5, 6, 7))

    def _make_dist_observe(self):
        """The dist observe stage — everything after the sharded apply,
        operating on the GLOBAL batch (per-node op counts, the sketch,
        the overload admission step, hop planning, replication-register
        advance, span sampling).  Shared verbatim by the per-epoch step
        (jitted at host level on the assembled decision) and the fused
        period program (run replicated inside the shard_map on the
        all_gathered decision), so the two are the same math."""
        cfg = self.cfg
        N = cfg.num_nodes
        mp = self.mode_plan
        ocfg = self.ovl_cfg
        tcfg = self.tel_cfg
        tel_thr = self._tel_threshold
        ccfg = self.coord_cfg
        hp = bool(getattr(self.directory, "hash_partitioned", False))
        mcfg = self.met_cfg
        met_topk = self.met_layout.topk if mcfg is not None else 0

        def observe(q, ridx, target, chain, chain_len, sketch, rng, repl,
                    picked, bounced, ovl, r_ovl, eid, coord, metrics):
            """Post-processing of the dist apply's decision."""
            B = target.shape[0]
            decision = C.RoutingDecision(
                ridx=ridx,
                target=target,
                chain=chain,
                chain_len=chain_len,
                clength=jnp.zeros_like(target),
            )
            node_ops = _node_ops(decision, q.opcode, N)
            sketch = sketch_update(sketch, q.key)
            bounce_kw = (dict(read_via=picked, read_bounce=bounced)
                         if mp.dirty_reads else {})
            # overload step: same accounting-plane placement as the oracle
            # body — after the distributed apply, deciding timing fate only
            ovl_pre = ovl
            if ocfg is not None:
                ovl, ovl_rej, ovl_scale, ovl_out, ostats = OVL.step(
                    ovl, target, r_ovl, ocfg
                )
                ovl_kw = dict(shed=ovl_rej, service_scale=ovl_scale)
                ovl, first_epoch = OVL.link_orbit(
                    ovl, q.key, ovl_rej,
                    ovl_out == OVL.OUTCOME_ADMITTED, eid,
                )
            else:
                ostats = jnp.zeros((len(OVL.STAT_FIELDS),), jnp.int32)
                ovl_kw = {}
                first_epoch = None
            # the coordination tier observes the global batch (same
            # accounting-plane placement as the oracle body: redirects
            # reprice hops, nothing else changes)
            if ccfg is not None:
                coord, redirect, redirect_via, cstats = CT.observe_epoch(
                    coord, q, decision, eid, quorum=ccfg.quorum,
                    hash_partitioned=hp,
                )
                coord_kw = dict(redirect=redirect,
                                redirect_via=redirect_via)
            else:
                redirect = None
                cstats = CT.empty_cstats()
                coord_kw = {}
            plan = plan_hops(
                q, decision, cfg.mode, cfg.latency, rng=rng, num_nodes=N,
                write_chain_cap=mp.write_cap_spread,
                service_model=cfg.service_model, **bounce_kw, **ovl_kw,
                **coord_kw,
            )
            if mp.track_state:
                is_write = (q.opcode == K.OP_PUT) | (q.opcode == K.OP_DEL)
                repl = RPL.advance(repl, ridx, is_write)
            span_bounced = (bounced if redirect is None
                            else bounced | redirect)
            if tcfg is not None:
                if ocfg is not None:
                    t_safe = jnp.clip(target, 0, N - 1)
                    qdepth = ovl_pre.queue[t_safe]
                    Lv = ovl_pre.retry.shape[1]
                    orbit_node = jnp.max(
                        jnp.where(
                            ovl_pre.retry > 0,
                            jnp.arange(1, Lv + 1, dtype=jnp.int32)[None, :],
                            0,
                        ),
                        axis=1,
                    ) - 1
                    orbit = orbit_node[t_safe]
                    outcome = ovl_out
                    scale_rec = ovl_scale
                else:
                    qdepth = jnp.zeros((B,), jnp.int32)
                    orbit = jnp.full((B,), -1, jnp.int32)
                    outcome = jnp.where(
                        target >= 0,
                        jnp.int32(OVL.OUTCOME_ADMITTED),
                        jnp.int32(OVL.OUTCOME_INVALID),
                    )
                    scale_rec = jnp.ones((B,), jnp.float32)
                spans = TEL.collect_spans(
                    q, eid, decision, picked, span_bounced, outcome, qdepth,
                    orbit, scale_rec, plan,
                    threshold=tel_thr, k_slots=tcfg.max_spans,
                    lookup=cfg.latency.lookup, first_epoch=first_epoch,
                )
            else:
                spans = None
            if mcfg is not None:
                # same end-of-epoch placement as the oracle body — the
                # observe stage runs replicated on the global batch, so
                # the ring row is identical on every device
                metrics = MTR.record_epoch(
                    metrics, node_ops=node_ops, ovl=ovl, ostats=ostats,
                    cstats=cstats, coord=coord, repl=repl, sketch=sketch,
                    keys=q.key, ridx=ridx, topk=met_topk,
                )
            return (sketch, plan, node_ops, repl, ovl, coord, metrics,
                    ostats, cstats, spans)

        return observe

    def _build_dist_step(self):
        from jax.sharding import NamedSharding, PartitionSpec

        cfg = self.cfg
        mp = self.mode_plan
        spread = mp.spread
        dist_apply = self._dist_apply
        # canonical layouts: replicated control state, node-sharded store.
        # Every call re-commits its inputs to these (a no-op at steady
        # state) — jit keys its cache on input commitment, so the mix of
        # committed step outputs and uncommitted host-built refresh tables
        # would otherwise compile the fused program twice (epoch 0 with
        # fresh host arrays, epoch 1 with device outputs: a hidden
        # retrace the `traces` gate now catches).
        rep = NamedSharding(self._mesh, PartitionSpec())
        shd = NamedSharding(self._mesh, PartitionSpec(self._dist_cfg.axis))
        ocfg = self.ovl_cfg
        use_qpen = self._dist_cfg.queue_pen
        observe_body = self._make_dist_observe()

        def observe(*args):
            self._traces += 1  # python side effect: counts traces
            return observe_body(*args)

        observe = jax.jit(observe)

        def step(store, directory, load_reg, sketch, repl, ovl, coord,
                 metrics, q, rng, eid):
            store = jax.device_put(store, shd)
            directory = jax.device_put(directory, rep)
            load_reg = jax.device_put(load_reg, rep)
            sketch = jax.device_put(sketch, rep)
            repl = jax.device_put(repl, rep)
            if coord is not None:
                coord = jax.device_put(coord, rep)
            if metrics is not None:
                metrics = jax.device_put(metrics, rep)
            if ovl is not None:
                ovl = jax.device_put(ovl, rep)
                r_ovl = jax.random.fold_in(rng, 0x0F10AD)
            else:
                r_ovl = rng  # unused placeholder, keeps observe uniform
            r_route, r_plan = jax.random.split(rng)
            B = q.opcode.shape[0]
            qp = ()
            if use_qpen:
                qp = (jax.device_put(
                    ovl.queue.astype(jnp.uint32)
                    * jnp.uint32(ocfg.queue_weight), rep
                ),)
            if mp.dirty_reads:
                dirty = jax.device_put(RPL.dirty_bits(repl), rep)
                store, _resp, directory, load_reg, m = dist_apply(
                    store, directory, load_reg, *qp, dirty, q, r_route
                )
                picked, bounced = m["picked"], m["bounced"]
            elif spread:
                store, _resp, directory, load_reg, m = dist_apply(
                    store, directory, load_reg, *qp, q, r_route
                )
                picked = bounced = None
            else:
                store, _resp, directory, m = dist_apply(store, directory, q)
                picked = bounced = None
            if picked is None:
                # placeholders keep observe's signature mode-independent
                picked = m["target"]
                bounced = jnp.zeros((B,), jnp.bool_)
            (sketch, plan, node_ops, repl, ovl, coord, metrics, ostats,
             cstats, spans) = observe(
                q, m["ridx"], m["target"], m["chain"], m["chain_len"], sketch,
                r_plan, repl, picked, bounced, ovl, r_ovl, eid, coord,
                metrics,
            )
            if not spread:
                load_reg = load_reg + node_ops.astype(jnp.uint32)
            return (store, directory, load_reg, sketch, repl, ovl, coord,
                    metrics, plan, node_ops, m["bucket_overflow"], bounced,
                    ostats, cstats, spans)

        return step

    def _build_dist_period(self):
        """The fused dist period program (the scale-out tentpole): the
        whole control period runs as ONE shard_map program with the
        ``lax.scan`` over epochs *inside* it (``make_dist_period``) — one
        dispatch and one compile per scenario, like the oracle scan,
        instead of one shard_map program per epoch.  Wrapped with the
        same canonical-sharding re-commit as the per-epoch step (jit keys
        its cache on input commitment) and exposing the oracle period
        fn's exact signature so ``_scan_segment`` drives both backends."""
        from jax.sharding import NamedSharding, PartitionSpec

        mp = self.mode_plan
        ocfg = self.ovl_cfg
        use_qpen = self._dist_cfg.queue_pen

        def pre(repl, ovl):
            # the per-epoch routing inputs the driver derives from carried
            # state between steps, now derived inside the scan body —
            # identical math on identical (pre-epoch) state
            queue_pen = None
            if use_qpen:
                queue_pen = ovl.queue.astype(jnp.uint32) * jnp.uint32(
                    ocfg.queue_weight
                )
            dirty = RPL.dirty_bits(repl) if mp.dirty_reads else None
            return dirty, queue_pen

        self._dist_period = make_dist_period(
            self._mesh, self.directory, self._dist_cfg,
            pre=pre, observe=self._make_dist_observe(),
            fold_ovl=ocfg is not None,
        )
        rep = NamedSharding(self._mesh, PartitionSpec())
        shd = NamedSharding(self._mesh, PartitionSpec(self._dist_cfg.axis))

        def period(store, directory, load_reg, sketch, repl, ovl, coord,
                   metrics, qs, rngs, live, eids):
            store = jax.device_put(store, shd)
            directory = jax.device_put(directory, rep)
            load_reg = jax.device_put(load_reg, rep)
            sketch = jax.device_put(sketch, rep)
            repl = jax.device_put(repl, rep)
            if ovl is not None:
                ovl = jax.device_put(ovl, rep)
            if coord is not None:
                coord = jax.device_put(coord, rep)
            if metrics is not None:
                metrics = jax.device_put(metrics, rep)
            return self._dist_period(
                store, directory, load_reg, sketch, repl, ovl, coord,
                metrics, qs, rngs, live, eids,
            )

        return period

    # -- host-side helpers -------------------------------------------------
    def _sync(self, x) -> np.ndarray:
        """Device->host transfer with bookkeeping (the profile metric the
        fused pipeline exists to minimize)."""
        self.host_syncs += 1
        return np.asarray(x)

    def _note_keys(self, keys) -> None:
        """Fold one epoch's keys into the distinct-key window (sorted-unique
        incremental merge; capped by uniform thinning)."""
        ek = np.unique(np.asarray(keys, np.uint32).ravel())
        self._key_window = _merge_unique(self._key_window, ek)
        cap = self.cfg.key_window_cap
        if cap and self._key_window.size > cap:
            stride = -(-self._key_window.size // cap)   # ceil div
            self._key_window = self._key_window[::stride]

    def _sketch_heat(self, sample: np.ndarray) -> np.ndarray:
        """Count-min estimates for the window, via a shape-stable padded
        query (per-epoch sample sizes vary; padding to a power-of-two
        bucket keeps the eager query from recompiling every pull — this
        was the single biggest per-epoch host cost before the fused
        pipeline)."""
        m = sample.size
        padded = 1 << max(6, (m - 1).bit_length())
        buf = np.full(padded, K.EMPTY_KEY, np.uint32)
        buf[:m] = sample
        heat = self._sync(sketch_query(self.sketch, jnp.asarray(buf)))
        return heat[:m].astype(np.float64)

    def _handle_events(self, e: int) -> tuple[list[str], int, int]:
        """Apply the scenario's control events for epoch ``e`` (host side;
        events only ever fire at epoch boundaries == segment starts)."""
        scfg = self.scenario.cfg
        events: list[str] = []
        mig_entries = mig_bytes = 0
        tables_changed = False
        for kind, node in self.scenario.events(e):
            if kind == "fail":
                # live node_load mid-period: counters are NOT reset here
                nl = self._sync(D.node_load(self.directory))
                ops = self.controller.handle_node_failure(node, nl)
                en, by = migration_traffic(self.store, ops, scfg.value_dim)
                self.store = execute_migrations(self.store, ops)
                self.directory = self.controller.refresh(self.directory)
                mig_entries += en
                mig_bytes += by
                tables_changed = True
                events.append(f"fail:{node}")
            elif kind == "rack_fail":
                # correlated failure: the switch fronting a rack dies and
                # every node behind it goes with it (paper §5.2); the
                # controller splices all of them before re-replicating so
                # repair copies never target a dead rack-mate
                rack = [int(n) for n in node]
                ops = self.controller.handle_switch_failure(rack)
                en, by = migration_traffic(self.store, ops, scfg.value_dim)
                self.store = execute_migrations(self.store, ops)
                self.directory = self.controller.refresh(self.directory)
                mig_entries += en
                mig_bytes += by
                tables_changed = True
                events.append("rack_fail:" + "+".join(map(str, rack)))
            elif kind == "recover":
                self.controller.recover_node(node)
                events.append(f"recover:{node}")
            elif kind in CT.EVENT_KINDS:
                # coordination-plane faults: meaningful only with the
                # tier on; the same scenario drives the no-tier baseline
                # arm, which simply ignores them
                if self.coord_mgr is not None:
                    with self._timers.stage("coord_control"):
                        self.coord, notes = self.coord_mgr.on_event(
                            kind, node, self.coord,
                            self.controller.table_snapshot(), now=e,
                        )
                    events.extend(notes)
        self._sync_repl()
        if self.coord_mgr is not None and tables_changed:
            # a failure splice is a control write like any other: it must
            # propagate along the switch chain (stale copies keep routing
            # to the spliced chain until their install lands — priced as
            # redirects, never served wrong under quorum reads)
            with self._timers.stage("coord_control"):
                self.coord, notes = self.coord_mgr.on_control(
                    self.coord, self.controller.table_snapshot(), now=e,
                )
            events.extend(notes)
        return events, mig_entries, mig_bytes

    def _sync_repl(self) -> None:
        """Replay the controller's reconfiguration journal onto the
        device-resident version/dirty register file (chain membership
        changes dirty conservatively, split children inherit — see
        ``repro.replication.state.apply_events``).  The journal is always
        drained (it must not grow unbounded) but only the tracking modes
        pay the host round-trip."""
        events = self.controller.drain_repl_log()
        if events and self.mode_plan.track_state:
            self.host_syncs += 1   # apply_events pulls the register file
            self.repl = RPL.apply_events(self.repl, events)

    def _control_pull(self, now: int) -> tuple[list[str], int, int]:
        """The period-boundary controller pull: harvest + reset counters,
        run the policy, execute its migration plan, graft the refreshed
        tables.  The ONLY counter/load-register reset path.  ``now`` is
        the epoch count at the pull (the boundary just completed)."""
        scfg = self.scenario.cfg
        self.host_syncs += 1   # pull_report harvests the device counters
        report, self.directory = pull_report(self.directory, self._period)
        self._period += 1
        if self._key_window.size:
            # count-min view of the period: distinct keys seen, with
            # their sketch heat estimates — the split policies place
            # boundaries at heat quantiles inside hot ranges
            sample = self._key_window
            heat = self._sketch_heat(sample)
            report = dataclasses.replace(
                report, key_sample=sample, key_heat=heat
            )
            self._key_window = np.empty(0, np.uint32)
        if self.mode_plan.spread:
            # directory.node_load charges every read to the chain tail;
            # under p2c spreading the data-plane load registers are the
            # truthful per-node picture — hand those to the policy so
            # widen/balance target selection doesn't chase tails
            report = dataclasses.replace(
                report,
                node_load=self._sync(self.load_reg).astype(np.float64),
            )
        if self.ovl is not None:
            # queue/retry view for the backpressure policies (host syncs
            # gated on the subsystem so the disabled path's sync count is
            # untouched)
            self.host_syncs += 1
            qd = np.asarray(self.ovl.queue).astype(np.int64)
            rb = np.asarray(self.ovl.retry).sum(axis=1).astype(np.int64)
            report = dataclasses.replace(
                report,
                queue_depth=qd,
                retry_backlog=rb,
                queue_limit=int(self.ovl_cfg.queue_cap),
                service_limit=int(self.ovl_cfg.service_rate),
            )
        if self.auto_period:
            # cadence-aware budgets: a period of k x the band minimum
            # gets k rounds' worth of per-round move/widen/split budget,
            # keeping the migration *rate* cadence-invariant
            span = max(now - self._last_pull_epoch, 1)
            report = dataclasses.replace(
                report,
                budget_scale=float(span) / float(self.cfg.auto_band[0]),
            )
        events: list[str] = []
        rb = getattr(self.policy.config, "redirect_backoff", 0.0)
        if rb > 0 and self._last_redirect_share > rb:
            # the switch fabric is still digesting the last
            # reconfiguration (redirect share above the policy's backoff
            # threshold): skip this round's policy consult entirely so
            # control churn stops widening the stale window
            ops = []
            events.append(
                f"redirect_backoff:{self._last_redirect_share:.3f}"
            )
        else:
            ops = self.policy.on_report(self.controller, report)
        # backpressure control channel: policies publish per-node
        # admission probabilities / retry budgets and free-form event
        # notes; graft them onto the device registers for the next period
        if self.ovl is not None:
            ap = getattr(self.policy, "admit_prob", None)
            if ap is not None:
                self.ovl = dataclasses.replace(
                    self.ovl, admit_prob=jnp.asarray(ap, jnp.float32)
                )
            rbud = getattr(self.policy, "retry_budget", None)
            if rbud is not None:
                self.ovl = dataclasses.replace(
                    self.ovl, retry_budget=jnp.asarray(rbud, jnp.int32)
                )
        notes = getattr(self.policy, "notes", None)
        if notes:
            events.extend(notes)
            notes.clear()
        mig_entries = mig_bytes = 0
        if ops:
            mig_entries, mig_bytes = migration_traffic(
                self.store, ops, scfg.value_dim
            )
            self.store = execute_migrations(self.store, ops)
            events.extend(f"{op.kind}:{op.src}->{op.dst}" for op in ops)
        if self.cfg.split_overflow:
            sops = self._capacity_splits(report)
            if sops:
                en, by = migration_traffic(self.store, sops, scfg.value_dim)
                self.store = execute_migrations(self.store, sops)
                mig_entries += en
                mig_bytes += by
                events.extend(f"{op.kind}:{op.src}->{op.dst}" for op in sops)
        grew = self.controller.num_slots != self.directory.chains.shape[0]
        if grew:
            # the slot pool grew under split_overflowed: shapes changed,
            # so refresh refuses by design — rebuild the device directory
            # and recompile the step.  The live counters were harvested
            # and reset by this very pull, so pending merge credits would
            # land on zeros; drop them with the old tables.
            self.controller.drop_credits()
            self.directory = self.controller.directory()
            self._rebuild_step()
            events.append(f"grow_pool:{self.controller.num_slots}")
        else:
            self.directory = self.controller.refresh(self.directory)
        self._sync_repl()
        if self.coord_mgr is not None:
            # the sync/stage/lease path is host control work like the
            # policy consult — timed under its own stage so the period
            # breakdown accounts for the coordination tier
            with self._timers.stage("coord_control"):
                snap = self.controller.table_snapshot()
                if grew:
                    # pool growth changes every table shape: full fabric
                    # resync at the new width (the step recompiles anyway
                    # — `traces` counts the growth, not a hidden retrace)
                    self.coord = self.coord_mgr.rebuild(snap)
                else:
                    # the period's control writes enter the switch chain:
                    # commit now, install per-switch with chain-position
                    # lag
                    self.coord, cnotes = self.coord_mgr.on_control(
                        self.coord, snap, now=now
                    )
                    events.extend(cnotes)
        if self.auto_period and now < self.scenario.cfg.n_epochs:
            # the pull at the final boundary has no next period to tune:
            # retuning there would append a period choice that never
            # executes (and, pre-fix, one computed without the realized
            # budget_scale) — drop it from period_history instead of
            # reporting a known-stale field
            nl = np.asarray(report.node_load, np.float64)
            if self.mode_plan.spread:
                # registers are cumulative-with-decay; the drift input is
                # this period's delta over the post-halving floor (the
                # non-spread path feeds pull_report counters, which ARE
                # reset per period — same semantics either way)
                self._auto_retune(nl - self._reg_floor, now)
                self._reg_floor = np.floor_divide(nl, 2)
            else:
                self._auto_retune(nl, now)
        # halve rather than zero: p2c needs *recent* load signal to keep
        # steering reads off write-busy heads; a hard reset degenerates
        # it to a uniform-random replica pick for the whole next period
        self.load_reg = self.load_reg // 2
        self.sketch = jnp.zeros_like(self.sketch)
        return events, mig_entries, mig_bytes

    def _auto_retune(self, node_load: np.ndarray, now: int) -> None:
        """Adaptive pull cadence: pick the next control period from
        report-to-report load drift, inside ``cfg.auto_band``.

        Drift is the L1 change of the *per-epoch-normalized* node-load
        vector relative to its previous mass (periods vary in length, so
        raw register sums are not comparable).  High drift (a moving
        hotspot) halves the period — control tightens; low drift doubles
        it — the data plane runs longer between host round-trips.  The
        fused scan is sized at the band maximum, so every period length
        in the band runs as a masked-padded segment of the one compiled
        program."""
        cfg = self.cfg
        lo, hi = int(cfg.auto_band[0]), int(cfg.auto_band[1])
        span = max(now - self._last_pull_epoch, 1)
        load = np.asarray(node_load, np.float64) / span
        prev = self._prev_load
        if prev is not None:
            mass = max(prev.sum(), 1e-9)
            drift = float(np.abs(load - prev).sum() / mass)
            if drift > cfg.auto_drift_hi:
                self._cur_period = max(lo, self._cur_period // 2)
            elif drift < cfg.auto_drift_lo:
                self._cur_period = min(hi, self._cur_period * 2)
        self._prev_load = load
        self._last_pull_epoch = now
        self._next_pull = now + self._cur_period
        self.period_history.append(self._cur_period)

    def _capacity_splits(self, report) -> list:
        """Capacity-driven splitting in the loop (paper §4.1.1): for each
        node whose store overflowed since the last pull, split the hottest
        live range it heads (``Controller.split_overflowed`` — which grows
        the slot pool when exhausted; the caller rebuilds the step)."""
        ovf = self._sync(self.store.overflow).astype(np.int64)
        delta = ovf - self._ovf_node_last
        self._ovf_node_last = ovf
        hot_nodes = [int(n) for n in np.argsort(-delta) if delta[n] > 0]
        if not hot_nodes:
            return []
        heat = (report.read_count + report.write_count).astype(np.float64)
        ctl = self.controller
        ops = []
        for node in hot_nodes:
            cands = [r for r in ctl.live_ranges()
                     if int(ctl.chain_nodes(r)[0]) == node]
            if not cands:
                continue
            # ranges born mid-loop (post-harvest) carry no heat yet
            ridx = max(cands,
                       key=lambda r: heat[r] if r < heat.size else 0.0)
            ops.extend(ctl.split_overflowed(ridx, report.node_load))
        return ops

    def _rebuild_step(self) -> None:
        """Recompile the device step after a pool growth (the one control
        action that changes array shapes).  The old program's compile
        count is banked in ``_trace_base`` so :attr:`traces` reports
        exactly ``1 + growth_events`` when recompiles only follow
        growth — the no-silent-retrace gate, now growth-aware."""
        if self.backend == "dist":
            # the dist programs close over no shapes: jit re-specializes
            # on the grown directory/repl arrays by itself, and the
            # traces property reads that cache — count the growth, keep
            # the program
            self.growth_events += 1
            return
        if self.fused:
            self._trace_base += _jit_cache_size(self._period_fn, 0)
            self._period_fn = self._build_oracle_period(self.mode_plan)
        else:
            self._trace_base += _jit_cache_size(self._step, 0)
            self._step = self._build_oracle_step(self.mode_plan)
        self.growth_events += 1

    # -- the per-epoch reference loop --------------------------------------
    def run_epoch(self, e: int) -> EpochMetrics:
        """One epoch, one host round-trip (the ``fused=False`` loop the
        period pipeline is asserted bit-identical against)."""
        if self._step is None:
            raise RuntimeError(
                "per-epoch stepping is unavailable on a fused driver; "
                "use run(), or construct with fused=False"
            )
        cfg = self.cfg
        scfg = self.scenario.cfg
        events, mig_entries, mig_bytes = self._handle_events(e)

        with self._timers.stage("inject"):
            opcodes, keys, end_keys, values = self.scenario.epoch(e)
            self._note_keys(keys)
            q = C.make_queries(
                jnp.asarray(keys), jnp.asarray(opcodes),
                jnp.asarray(values), jnp.asarray(end_keys),
            )
            rng = jax.random.fold_in(self.key, e)
        with self._timers.stage("route_apply"):
            out = self._step(
                self.store, self.directory, self.load_reg, self.sketch,
                self.repl, self.ovl, self.coord, self.metrics, q, rng,
                jnp.int32(e)
            )
            if self._timers.enabled:
                # profiling measures execution, not dispatch; values are
                # untouched (an explicit, wall-time-only observer effect)
                jax.block_until_ready(out)
        (self.store, self.directory, self.load_reg, self.sketch, self.repl,
         self.ovl, self.coord, self.metrics, plan, node_ops, retries,
         bounced, ostats, cstats, spans) = out

        self.host_syncs += 1   # the DES engine pulls the plan to the host
        issue = hops = None
        with self._timers.stage("des"):
            if self.telemetry is not None:
                latency, makespan, issue, hops = C.simulate_closed_loop(
                    plan,
                    n_clients=cfg.n_clients,
                    num_nodes=cfg.num_nodes,
                    link=cfg.latency.link,
                    backend=cfg.des_backend,
                    return_issue=True,
                    return_hops=True,
                )
            else:
                latency, makespan = C.simulate_closed_loop(
                    plan,
                    n_clients=cfg.n_clients,
                    num_nodes=cfg.num_nodes,
                    link=cfg.latency.link,
                    backend=cfg.des_backend,
                )
        lat = np.asarray(latency)[None]
        (p50,), (p99,) = latency_percentiles_batch(lat)
        (p999,) = p999_batch(lat)
        mk = float(np.asarray(makespan))

        is_read = ((opcodes == K.OP_GET) | (opcodes == K.OP_SCAN))[None]
        if self.mode_plan.dirty_reads:
            bounced_h = self._sync(bounced).astype(bool)[None]
        else:
            bounced_h = np.zeros_like(is_read)
        (read_p99,) = masked_p99_batch(lat, is_read)
        (clean_p99,) = masked_p99_batch(lat, is_read & ~bounced_h)
        dirty_reads = int(bounced_h.sum())

        live = self._live_mask()
        (imb,), (cov,) = imbalance_stats_batch(
            self._sync(node_ops)[None], live
        )

        # drops = pure store-capacity overflow delta; the overload plane's
        # shed/requeued/lost travel separately (the satellite fix for the
        # old conflation of capacity events with shed traffic)
        overflow_now = int(self._sync(self.store.overflow).sum())
        drops = overflow_now - self._last_overflow
        self._last_overflow = overflow_now
        if self.ovl is not None:
            ost = self._sync(ostats).astype(np.int64)
        else:
            ost = np.zeros((len(OVL.STAT_FIELDS),), np.int64)
        if self.coord is not None:
            cst = self._sync(cstats).astype(np.int64)
            if cst[0] > 0:
                self._last_redirect_share = float(cst[2]) / float(cst[0])
        else:
            cst = np.zeros((len(CT.CSTAT_FIELDS),), np.int64)

        # ---- control pull: the only counter/load-register reset path ----
        pull = ((e + 1) == self._next_pull if self.auto_period
                else (e + 1) % self.period == 0)
        if pull:
            pev, pen, pby = self._control_pull(e + 1)
            events.extend(pev)
            mig_entries += pen
            mig_bytes += pby

        row = EpochMetrics(
            epoch=e,
            scenario=self.scenario.name,
            policy=self.policy.name,
            ops=scfg.epoch_ops,
            throughput=scfg.epoch_ops / mk if mk > 0 else 0.0,
            p50=p50,
            p99=p99,
            makespan=mk,
            imbalance=imb,
            cov=cov,
            migration_entries=mig_entries,
            migration_bytes=mig_bytes,
            drops=drops,
            retries=int(self._sync(retries)),
            compiled_steps=self.traces,
            events=events,
            p999=float(p999),
            read_p99=float(read_p99),
            clean_read_p99=float(clean_p99),
            dirty_reads=dirty_reads,
            replication=cfg.replication_mode,
            deferred=int(ost[2]),
            shed=int(ost[3]),
            requeued=int(ost[4]),
            lost=int(ost[5]),
            queue_peak=int(ost[6]),
            routed=int(cst[0]),
            direct=int(cst[1]),
            redirected=int(cst[2]),
            mis_served=int(cst[3]),
            stale_switches=int(cst[4]),
            coordination=self._coord_label(),
        )
        if self.telemetry is not None:
            si, sf, cnt = spans
            self.host_syncs += 1   # span tables + state snapshot pull
            self.telemetry.on_segment(
                e, [row],
                np.asarray(si)[None], np.asarray(sf)[None],
                np.asarray(cnt)[None], lat,
                None if issue is None else np.asarray(issue)[None],
                np.asarray([mk]), self._state_snapshot(),
                hops=None if hops is None else np.asarray(hops)[None],
            )
        # fold the host-computed columns into the ring row the device
        # just wrote, then evaluate the SLO burn rates — L == 1 here, so
        # the cells and values are bitwise the fused path's (parity
        # contract on every ring leaf).  After on_segment: a burn alert's
        # flight dump must include this epoch's ring entry.
        self._fold_metrics(e, 1, [p50], [p99], [p999], [imb])
        return row

    def _state_snapshot(self) -> dict:
        """Host view of the carried state for the flight-recorder ring
        (telemetry-only path; its syncs are counted by the caller)."""
        snap: dict = {
            "load_reg": np.asarray(self.load_reg).astype(np.int64).tolist(),
        }
        if self.ovl is not None:
            snap["queue_depth"] = np.asarray(self.ovl.queue).tolist()
            snap["retry_backlog"] = int(np.asarray(self.ovl.retry).sum())
            snap["conservation_gap"] = OVL.conservation_gap(self.ovl)
        if self.mode_plan.track_state:
            snap["replication"] = RPL.summary(self.repl)
        if self.coord_mgr is not None:
            snap["coordination"] = self.coord_mgr.summary()
        return snap

    def _coord_label(self) -> str:
        """The metric-row coordination arm label ("none" when the tier is
        off — the pre-tier rows round-trip unchanged)."""
        if self.coord_cfg is None:
            return "none"
        return "quorum" if self.coord_cfg.quorum else "no-quorum"

    def _live_mask(self) -> np.ndarray:
        """(N,) bool serving mask: failed AND standby nodes are out of the
        imbalance denominator (a parked node's zero load is by design)."""
        out = self.controller.failed | self.controller.standby
        return np.array([n not in out for n in range(self.cfg.num_nodes)])

    def overload_summary(self) -> dict:
        """Host snapshot of the overload plane (empty when disabled)."""
        if self.ovl is None:
            return {}
        return OVL.summary(self.ovl)

    # -- the fleet metrics plane -------------------------------------------
    def _fold_metrics(self, e0: int, L: int, p50s, p99s, p999s, imbs
                      ) -> None:
        """Segment-boundary metrics work: fold the host-computed latency/
        imbalance columns into the ``L`` ring rows the device just wrote,
        then evaluate the SLO burn rates on device and feed the alert
        engine (one extra host sync, gated on the plane so the disabled
        path's sync count is untouched)."""
        if self.metrics is None:
            return
        with self._timers.stage("metrics"):
            vals = np.stack([
                np.asarray(p50s, np.float64).reshape(-1)[:L],
                np.asarray(p99s, np.float64).reshape(-1)[:L],
                np.asarray(p999s, np.float64).reshape(-1)[:L],
                np.asarray(imbs, np.float64).reshape(-1)[:L],
            ], axis=1)
            self.metrics = MTR.fold_host(
                self.metrics, self._met_pos, vals, self.met_layout.host_cols
            )
            self._met_pos += L
            if self.met_cfg.slos:
                res = SLOM.evaluate_segment(
                    self.metrics, self.met_layout, self.met_cfg.slos, L
                )
                self.host_syncs += 1   # the burn-rate arrays come home
                self.met_engine.observe(e0, res)

    def _on_slo_fire(self, spec, ev: dict) -> None:
        """Rising-edge hook: a burn alert is an invariant breach — dump
        the PR-7 flight ring with the SLO context in the reason."""
        if self.telemetry is not None:
            self.telemetry.breach(
                f"slo_burn:{spec.name}:epoch {ev['epoch']} "
                f"value {ev['value']:.2f} > {spec.bound} "
                f"fast {ev['fast_burn']:.2f} slow {ev['slow_burn']:.2f}"
            )

    def metrics_view(self) -> dict:
        """Chronological host view of the metrics ring (one sync)."""
        if self.metrics is None:
            raise ValueError("metrics plane disabled (metrics=None)")
        self.host_syncs += 1
        return MTR.series_view(self.metrics, self.met_layout)

    def alert_timeline(self) -> list[dict]:
        """The SLO alert timeline so far (empty when no SLOs fired)."""
        if self.met_engine is None:
            return []
        return list(self.met_engine.timeline)

    # -- the fused period loop ---------------------------------------------
    def _segment_len(self, e0: int, n: int) -> int:
        """Epochs until the next host intervention: the period boundary,
        the run end, or the next scenario control event."""
        if self.auto_period:
            next_pull = self._next_pull
        else:
            next_pull = ((e0 // self.period) + 1) * self.period
        # clamp to the scan length: a stale _next_pull (e.g. a timing
        # re-drive of an already-run auto-cadence driver) must never ask
        # for a segment longer than the compiled program
        end = min(next_pull, e0 + self.period, n)
        for e2 in range(e0 + 1, end):
            if e2 in self._event_epochs:
                return e2 - e0
        return max(end - e0, 1)

    def _scan_segment(self, e0: int, L: int):
        """Stage a segment's queries and run the donated period scan."""
        P = self.period
        with self._timers.stage("inject"):
            op_l, key_l, end_l, val_l = [], [], [], []
            for i in range(L):
                opcodes, keys, end_keys, values = self.scenario.epoch(e0 + i)
                self._note_keys(keys)
                op_l.append(opcodes)
                key_l.append(keys)
                end_l.append(end_keys)
                val_l.append(values)
            opcodes_h = np.stack(op_l)    # (L, B) host view for read masks
            for _ in range(L, P):   # pad with masked no-op epochs
                op_l.append(op_l[-1])
                key_l.append(key_l[-1])
                end_l.append(end_l[-1])
                val_l.append(val_l[-1])
            qs = C.make_queries(
                jnp.asarray(np.stack(key_l)), jnp.asarray(np.stack(op_l)),
                jnp.asarray(np.stack(val_l)), jnp.asarray(np.stack(end_l)),
            )
            rngs = jax.vmap(lambda i: jax.random.fold_in(self.key, i))(
                jnp.arange(e0, e0 + P)
            )
            live = jnp.asarray(np.arange(P) < L)
            eids = jnp.arange(e0, e0 + P, dtype=jnp.int32)
        with self._timers.stage("route_apply"):
            out = self._period_fn(
                self.store, self.directory, self.load_reg, self.sketch,
                self.repl, self.ovl, self.coord, self.metrics, qs, rngs,
                live, eids,
            )
            if self._timers.enabled:
                # profiling measures execution, not dispatch; values are
                # untouched (an explicit, wall-time-only observer effect)
                jax.block_until_ready(out)
        (self.store, self.directory, self.load_reg, self.sketch, self.repl,
         self.ovl, self.coord, self.metrics, plan, node_ops, retries, ovf,
         bounced, ostats, cstats, spans) = out
        return (jax.tree.map(lambda x: x[:L], plan),
                node_ops[:L], retries[:L], ovf[:L], bounced[:L], ostats[:L],
                cstats[:L],
                None if spans is None
                else jax.tree.map(lambda x: x[:L], spans),
                opcodes_h)

    def _step_segment(self, e0: int, L: int):
        """Per-epoch dist segment (the ``fused=False`` reference loop):
        one shard_map program per epoch with all host syncs deferred to
        the period boundary — plans/metrics stay on device until then.
        The fused dist driver runs the same period through
        :meth:`_scan_segment` instead (scan inside the shard_map)."""
        plans, nops_l, rtr_l, ovf_l, bnc_l, ost_l, cst_l, spn_l, op_l = (
            [], [], [], [], [], [], [], [], []
        )
        with self._timers.stage("route_apply"):
            for i in range(L):
                opcodes, keys, end_keys, values = self.scenario.epoch(e0 + i)
                self._note_keys(keys)
                op_l.append(opcodes)
                q = C.make_queries(
                    jnp.asarray(keys), jnp.asarray(opcodes),
                    jnp.asarray(values), jnp.asarray(end_keys),
                )
                rng = jax.random.fold_in(self.key, e0 + i)
                (self.store, self.directory, self.load_reg, self.sketch,
                 self.repl, self.ovl, self.coord, self.metrics, plan,
                 node_ops, retries, bounced, ostats, cstats,
                 spans) = self._step(
                    self.store, self.directory, self.load_reg, self.sketch,
                    self.repl, self.ovl, self.coord, self.metrics, q, rng,
                    jnp.int32(e0 + i)
                )
                plans.append(plan)
                nops_l.append(node_ops)
                rtr_l.append(retries)
                ovf_l.append(jnp.sum(self.store.overflow))
                bnc_l.append(bounced)
                ost_l.append(ostats)
                cst_l.append(cstats)
                spn_l.append(spans)
        plan = jax.tree.map(lambda *xs: jnp.stack(xs), *plans)
        spans = (None if spn_l[0] is None
                 else jax.tree.map(lambda *xs: jnp.stack(xs), *spn_l))
        return (plan, jnp.stack(nops_l), jnp.stack(rtr_l), jnp.stack(ovf_l),
                jnp.stack(bnc_l), jnp.stack(ost_l), jnp.stack(cst_l), spans,
                np.stack(op_l))

    def _run_segment(self, e0: int, n: int) -> list[EpochMetrics]:
        ev0, en0, by0 = self._handle_events(e0)
        L = self._segment_len(e0, n)
        if self._period_fn is not None:
            (plan, node_ops, retries, ovf, bounced, ostats, cstats, spans,
             opcodes_h) = self._scan_segment(e0, L)
        else:
            (plan, node_ops, retries, ovf, bounced, ostats, cstats, spans,
             opcodes_h) = self._step_segment(e0, L)

        cfg = self.cfg
        scfg = self.scenario.cfg
        # ---- ONE host round-trip for the whole segment ----
        self.host_syncs += 1   # the DES engine pulls the stacked plans
        issue = hops = None
        with self._timers.stage("des"):
            if self.telemetry is not None:
                latency, makespan, issue, hops = C.simulate_closed_loop(
                    plan,
                    n_clients=cfg.n_clients,
                    num_nodes=cfg.num_nodes,
                    link=cfg.latency.link,
                    backend=cfg.des_backend,
                    return_issue=True,
                    return_hops=True,
                )
            else:
                latency, makespan = C.simulate_closed_loop(
                    plan,
                    n_clients=cfg.n_clients,
                    num_nodes=cfg.num_nodes,
                    link=cfg.latency.link,
                    backend=cfg.des_backend,
                )
        with self._timers.stage("host_sync"):
            lat = np.asarray(latency)
            mks = np.asarray(makespan)
            node_ops_h = self._sync(node_ops)
            retries_h = self._sync(retries)
            ovf_h = self._sync(ovf).astype(np.int64)

        p50s, p99s = latency_percentiles_batch(lat)
        p999s = p999_batch(lat)
        is_read = (opcodes_h == K.OP_GET) | (opcodes_h == K.OP_SCAN)
        if self.mode_plan.dirty_reads:
            bounced_h = self._sync(bounced).astype(bool)
        else:
            bounced_h = np.zeros_like(is_read)
        read_p99s = masked_p99_batch(lat, is_read)
        clean_p99s = masked_p99_batch(lat, is_read & ~bounced_h)
        dirty_counts = bounced_h.sum(axis=1)
        live = self._live_mask()
        imbs, covs = imbalance_stats_batch(node_ops_h, live)
        drops = np.diff(ovf_h, prepend=np.int64(self._last_overflow))
        self._last_overflow = int(ovf_h[-1])
        if self.ovl is not None:
            ost_h = self._sync(ostats).astype(np.int64)        # (L, 7)
        else:
            ost_h = np.zeros((L, len(OVL.STAT_FIELDS)), np.int64)
        if self.coord is not None:
            cst_h = self._sync(cstats).astype(np.int64)        # (L, 5)
            seg_routed = int(cst_h[:, 0].sum())
            if seg_routed > 0:
                # the redirect-backoff signal the NEXT pull's policy
                # consult reads — update before the pull below
                self._last_redirect_share = (
                    float(cst_h[:, 2].sum()) / seg_routed
                )
        else:
            cst_h = np.zeros((L, len(CT.CSTAT_FIELDS)), np.int64)

        pulled = ((e0 + L) == self._next_pull if self.auto_period
                  else (e0 + L) % self.period == 0)
        pev: list[str] = []
        pen = pby = 0
        if pulled:
            with self._timers.stage("control"):
                pev, pen, pby = self._control_pull(e0 + L)

        rows = []
        for i in range(L):
            mk = float(mks[i])
            events: list[str] = []
            mig_entries = mig_bytes = 0
            if i == 0:
                events.extend(ev0)
                mig_entries += en0
                mig_bytes += by0
            if i == L - 1 and pulled:
                events.extend(pev)
                mig_entries += pen
                mig_bytes += pby
            rows.append(EpochMetrics(
                epoch=e0 + i,
                scenario=self.scenario.name,
                policy=self.policy.name,
                ops=scfg.epoch_ops,
                throughput=scfg.epoch_ops / mk if mk > 0 else 0.0,
                p50=float(p50s[i]),
                p99=float(p99s[i]),
                makespan=mk,
                imbalance=float(imbs[i]),
                cov=float(covs[i]),
                migration_entries=mig_entries,
                migration_bytes=mig_bytes,
                drops=int(drops[i]),
                retries=int(retries_h[i]),
                compiled_steps=self.traces,
                events=events,
                p999=float(p999s[i]),
                read_p99=float(read_p99s[i]),
                clean_read_p99=float(clean_p99s[i]),
                dirty_reads=int(dirty_counts[i]),
                replication=cfg.replication_mode,
                deferred=int(ost_h[i, 2]),
                shed=int(ost_h[i, 3]),
                requeued=int(ost_h[i, 4]),
                lost=int(ost_h[i, 5]),
                queue_peak=int(ost_h[i, 6]),
                routed=int(cst_h[i, 0]),
                direct=int(cst_h[i, 1]),
                redirected=int(cst_h[i, 2]),
                mis_served=int(cst_h[i, 3]),
                stale_switches=int(cst_h[i, 4]),
                coordination=self._coord_label(),
            ))
        if self.telemetry is not None:
            with self._timers.stage("telemetry"):
                si, sf, cnt = spans
                self.host_syncs += 1   # span tables + state snapshot pull
                self.telemetry.on_segment(
                    e0, rows, np.asarray(si), np.asarray(sf),
                    np.asarray(cnt), lat, issue, mks,
                    self._state_snapshot(), hops=hops,
                )
        # after on_segment: a burn alert firing in this segment dumps a
        # flight ring that already holds the segment's entries
        self._fold_metrics(e0, L, p50s, p99s, p999s, imbs)
        return rows

    def run(self) -> list[EpochMetrics]:
        tcfg = self.tel_cfg
        if tcfg is not None and tcfg.jax_trace_dir:
            # capture the whole run in a jax.profiler trace (TensorBoard/
            # Perfetto-loadable) alongside the span-plane artifacts
            with jax.profiler.trace(tcfg.jax_trace_dir):
                return self._run_all()
        return self._run_all()

    def _run_all(self) -> list[EpochMetrics]:
        n = self.scenario.cfg.n_epochs
        if not self.fused:
            return [self.run_epoch(e) for e in range(n)]
        rows: list[EpochMetrics] = []
        e = 0
        while e < n:
            rows.extend(self._run_segment(e, n))
            e = rows[-1].epoch + 1
        return rows

"""The balancing-policy zoo (control plane of the closed loop, §5.1).

A policy consumes the controller pull (:class:`~repro.core.stats.StatsReport`
plus an optional count-min top-range view) and mutates the controller's
tables, returning the migration plan the data movers execute.  Three knobs
exist, and each policy turns a different subset:

* **migration** — the paper's hottest-range -> coolest-node greedy move
  (``Controller.balance``);
* **selective replication** — widen the chain of sketch-identified hot
  ranges (``Controller.widen_chain``), narrow them again when they cool;
* **read spreading** — route GETs by power-of-two-choices over the live
  chain (``routing.route_load_aware``) instead of tail-only.  This is a
  *data-plane* knob: the policy only declares it (``read_spread``), the
  epoch driver compiles the matching step variant.

The bench compares ``frozen`` (directory never changes — the no-switch
baseline), ``migrate`` (paper behaviour), ``replicate`` (widen + spread,
no moves) and ``full_adaptive`` (everything on).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.controller import Controller
from repro.core.migration import MigrationOp
from repro.core.stats import StatsReport


@dataclasses.dataclass
class PolicyConfig:
    # widen a range when its heat *per live replica* exceeds this multiple
    # of the mean range heat
    hot_factor: float = 1.5
    # cap on replicas added per report (hottest ranges first)
    max_widen_per_round: int = 8
    # shrink a widened chain when its heat falls back under the mean
    narrow_below_mean: bool = True
    # chains never shrink below this (the configured replication factor)
    base_replication: int = 2


class Policy:
    """Base policy: freeze the directory (no control actions at all)."""

    name = "frozen"
    read_spread = False     # epoch driver compiles tail-read step

    def __init__(self, config: PolicyConfig | None = None):
        self.config = config or PolicyConfig()

    def on_report(self, controller: Controller, report: StatsReport
                  ) -> list[MigrationOp]:
        return []


class MigratePolicy(Policy):
    """Paper §5.1 behaviour: statistics-driven sub-range migration only."""

    name = "migrate"

    def on_report(self, controller, report):
        return controller.balance(report)


class ReplicatePolicy(Policy):
    """Hot-range selective replication + load-aware read spreading.

    Widens the chains of ranges whose *per-replica* heat dominates the
    mean — possibly by several replicas in one round — and narrows cooled
    chains back to the base replication.  Declares ``read_spread``
    because widening without spreading is pointless: tail-only reads
    would simply all move to the newcomer.

    Two details matter in practice (found the hard way):

    * consecutive widenings must account for the load they just shifted —
      picking "the coldest node" from a stale report piles every new
      replica onto the same three nodes and simply relocates the hotspot;
    * widened members are lazily-refreshed *read replicas*: the write's
      client-visible path stays the base chain (``plan_hops
      write_chain_cap``), and this policy re-emits a refresh copy per
      standing widened replica each round — the sync traffic the bench
      charges as migration bytes.
    """

    name = "replicate"
    read_spread = True

    def on_report(self, controller, report):
        cfg = self.config
        heat = (report.read_count + report.write_count).astype(np.float64)
        mean = heat.mean() if heat.size else 0.0
        ops: list[MigrationOp] = []
        if mean <= 0:
            return ops
        nl = report.node_load.astype(np.float64).copy()
        clen = controller.chain_lengths().astype(np.float64)
        budget = cfg.max_widen_per_round

        # hottest per live replica first: a wide warm chain is already
        # fine; fully-spliced chains (clen 0 after cascaded failures)
        # carry no replica to widen from and are masked out
        ratio = np.where(clen > 0, heat / np.maximum(clen, 1.0), -1.0)
        for ridx in np.argsort(ratio)[::-1]:
            if budget <= 0 or ratio[ridx] <= 0:
                break
            while budget > 0 and heat[ridx] / clen[ridx] > cfg.hot_factor * mean:
                op = controller.widen_chain(int(ridx), nl)
                if op is None:
                    break
                ops.append(op)
                budget -= 1
                # re-estimate: members shed read share, newcomer takes one
                c = clen[ridx]
                for m in controller.chain_nodes(int(ridx))[: int(c)]:
                    nl[int(m)] -= heat[ridx] / (c * (c + 1))
                nl[op.dst] += heat[ridx] / (c + 1)
                clen[ridx] += 1

        cl = controller.chain_lengths()
        if cfg.narrow_below_mean:
            for ridx in np.where(cl > cfg.base_replication)[0]:
                if heat[ridx] < mean:
                    op = controller.narrow_chain(int(ridx), cfg.base_replication)
                    if op is not None:
                        ops.append(op)
            cl = controller.chain_lengths()

        # periodic refresh of standing read replicas (lazy delta sync)
        for ridx in np.where(cl > cfg.base_replication)[0]:
            lo, hi = controller.range_span(int(ridx))
            chain = controller.chain_nodes(int(ridx))
            head = int(chain[0])
            for pos in range(cfg.base_replication, int(cl[ridx])):
                dst = int(chain[pos])
                if dst >= 0 and not any(
                    o.kind == "copy" and o.dst == dst and o.lo == lo
                    for o in ops
                ):
                    ops.append(MigrationOp(lo=lo, hi=hi, src=head, dst=dst,
                                           kind="copy"))
        return ops


class FullAdaptivePolicy(ReplicatePolicy):
    """Everything on: replicate + spread (inherited) and migrate.

    Replication handles ranges too hot for any single tail; migration
    evens out the residual per-node imbalance the replicas leave behind.
    """

    name = "full_adaptive"

    def on_report(self, controller, report):
        ops = super().on_report(controller, report)
        ops.extend(controller.balance(report))
        return ops


POLICIES = {
    "frozen": Policy,
    "migrate": MigratePolicy,
    "replicate": ReplicatePolicy,
    "full_adaptive": FullAdaptivePolicy,
}


def make_policy(name: str, config: PolicyConfig | None = None) -> Policy:
    if name not in POLICIES:
        raise ValueError(f"unknown policy {name!r}; pick from {sorted(POLICIES)}")
    return POLICIES[name](config)

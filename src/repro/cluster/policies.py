"""The balancing-policy zoo (control plane of the closed loop, §5.1).

A policy consumes the controller pull (:class:`~repro.core.stats.StatsReport`
plus the count-min key-heat view) and mutates the controller's tables,
returning the migration plan the data movers execute.  Four knobs exist,
and each policy turns a different subset:

* **migration** — the paper's hottest-range -> coolest-node greedy move
  (``Controller.balance``);
* **selective replication** — widen the chain of sketch-identified hot
  ranges (``Controller.widen_chain``), narrow them again when they cool;
* **read spreading** — route GETs by power-of-two-choices over the live
  chain (``routing.route_load_aware``) instead of tail-only.  This is a
  *data-plane* knob: the policy only declares it (``read_spread``), the
  epoch driver compiles the matching step variant;
* **hot-subset splitting** — the paper's §5.1 "a subset of the hot data":
  split a hot range at a count-min heat quantile
  (``Controller.split_range``; the split itself moves no data) so
  subsequent moves/replicas touch only the hot child's keys, and merge
  the child back (``Controller.merge_range``) with hysteresis once its
  heat subsides.

The bench compares ``frozen`` (directory never changes — the no-switch
baseline), ``migrate`` (paper behaviour), ``replicate`` (widen + spread,
no moves), ``split_hot`` (split + migrate — whole-range moves replaced by
hot-subset moves) and ``full_adaptive`` (everything on).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.controller import Controller
from repro.core.migration import MigrationOp
from repro.core.stats import StatsReport


@dataclasses.dataclass
class PolicyConfig:
    # widen a range when its heat *per live replica* exceeds this multiple
    # of the mean range heat
    hot_factor: float = 1.5
    # cap on replicas added per report (hottest ranges first)
    max_widen_per_round: int = 8
    # shrink a widened chain when its heat falls back under the mean
    narrow_below_mean: bool = True
    # chains never shrink below this (the configured replication factor)
    base_replication: int = 2

    # ---- hot-subset splitting (slot-pool) ----
    # split a range when its heat exceeds this multiple of the live mean
    split_factor: float = 2.0
    # cap on splits per report (hottest ranges first)
    max_splits_per_round: int = 4
    # never split a span narrower than this many matching values
    min_split_span: int = 4096
    # merge hysteresis: a child is "cool" when its heat drops below this
    # multiple of the live mean ...
    merge_factor: float = 0.75
    # ... for this many consecutive reports
    merge_patience: int = 2
    # lineage compaction: re-parent dangling/deep split lineage each
    # report so `generation` stays bounded (Controller.compact_lineage).
    # On by default: rescued orphans merge where they previously could
    # not, keeping long adversarial split runs from growing the lineage
    # without bound.  Set to None to leave lineage untouched (the pre-PR-8
    # behaviour, bit-comparable with the PR-3/4 gate-matrix rows).
    max_lineage_depth: int | None = 3

    # ---- overload backpressure (repro.overload; OverloadAdaptivePolicy) ----
    # AIMD admission control on queue occupancy (depth / queue_limit):
    admit_hi: float = 0.75        # above -> multiplicative decrease
    admit_lo: float = 0.25        # below -> additive recovery
    admit_decrease: float = 0.5   # the multiplicative cut
    admit_increase: float = 0.1   # the additive step back toward 1.0
    admit_floor: float = 0.05     # never fully closed (probes recovery)
    # retry budget as a fraction of the per-epoch service rate: caps how
    # much of a synchronized backlog release re-enters per epoch
    retry_frac: float = 0.25
    # capacity autoscale bands on mean queue occupancy over serving nodes
    scale_up_util: float = 0.5    # above (or any retry backlog) -> activate
    scale_down_util: float = 0.1  # below, with empty backlog -> park
    scale_patience: int = 2       # consecutive reports before acting
    min_serving: int = 2          # never park below this many live nodes

    # ---- coordination-tier backoff (repro.coordination_tier) ----
    # skip a policy round entirely when the previous period's redirect
    # share (redirected / routed, from the switch tier's conservation
    # counters) exceeds this: the fabric is still digesting the last
    # reconfiguration, and more migrations would only widen the stale
    # window.  0.0 (the default) disables the check bit-identically.
    redirect_backoff: float = 0.0


class Policy:
    """Base policy: freeze the directory (no control actions at all)."""

    name = "frozen"
    read_spread = False     # epoch driver compiles tail-read step
    # declared pull cadence: epochs per controller pull.  This is the
    # period the fused epoch driver runs device-resident between host
    # round-trips when ``ClusterConfig.report_every`` is left unset — a
    # policy that tolerates staler reports can raise it and trade control
    # lag for data-plane throughput (NetCache-style: many data intervals
    # per control pull).  The string ``"auto"`` delegates the choice to
    # the driver's drift-adaptive cadence (``ClusterConfig.auto_band``):
    # each report's node-load drift against the previous one shortens or
    # lengthens the next period inside the band.  Policy decisions are a
    # pure function of the period-boundary report either way.
    pull_every: int | str = 1

    def __init__(self, config: PolicyConfig | None = None):
        self.config = config or PolicyConfig()

    def on_report(self, controller: Controller, report: StatsReport
                  ) -> list[MigrationOp]:
        return []


class MigratePolicy(Policy):
    """Paper §5.1 behaviour: statistics-driven sub-range migration only."""

    name = "migrate"

    def on_report(self, controller, report):
        return controller.balance(report)


def _live_heat(controller: Controller, report: StatsReport):
    """(heat (S,), live (S,), live-mean) with dead slots zeroed out."""
    heat = (report.read_count + report.write_count).astype(np.float64)
    if report.live is not None:
        live = np.asarray(report.live, bool)
    else:
        live = np.zeros(len(heat), bool)
        live[controller.live_ranges()] = True
    heat = np.where(live, heat, 0.0)
    mean = heat[live].mean() if live.any() else 0.0
    return heat, live, mean


def _sketch_boundary(lo: int, hi: int, report: StatsReport) -> int | None:
    """Heat-median split boundary for [lo, hi] from the count-min view.

    The sampled keys inside the span, weighted by their ``sketch_query``
    estimates, give the period's heat distribution over the range; the
    weighted median is the boundary that splits that heat in half — the
    quantile split the whole-range counters cannot see.  None when the
    sketch view is absent or too thin (callers fall back to the midpoint).
    """
    if report.key_sample is None or report.key_heat is None:
        return None
    ks = report.key_sample.astype(np.uint64)
    w = report.key_heat.astype(np.float64)
    m = (ks >= lo) & (ks <= hi)
    ks, w = ks[m], w[m]
    if ks.size < 2 or w.sum() <= 0:
        return None
    order = np.argsort(ks)
    ks, w = ks[order], w[order]
    cum = np.cumsum(w)
    j = int(np.searchsorted(cum, cum[-1] * 0.5))
    j = min(j, ks.size - 2)
    return int(max(lo, min(int(ks[j]), hi - 1)))


class _SplitMergeMixin:
    """Shared hot-subset split / hysteresis-merge machinery.

    Splitting never moves data (the child inherits the parent's chain);
    the win is that every subsequent control action on the child — a
    migration or a widened replica — is priced by the hot subset's keys
    only.  Merging re-coalesces cooled children so the live record count
    (and the slot pool) does not ratchet upward over a long run.
    """

    def __init__(self, config: PolicyConfig | None = None):
        super().__init__(config)
        self._cool: dict[int, int] = {}   # child slot -> consecutive cool reports

    def split_merge(self, controller: Controller, report: StatsReport
                    ) -> list[MigrationOp]:
        cfg = self.config
        heat, live, mean = _live_heat(controller, report)
        ops: list[MigrationOp] = []
        if mean <= 0:
            return ops

        # ---- splits: hottest ranges first, boundary at the sketch median
        # (budget_scale: cadence-aware — k epochs of report get k rounds'
        # worth; 1.0 on fixed cadence, so the integer is unchanged there)
        budget = max(1, int(round(cfg.max_splits_per_round
                                  * report.budget_scale)))
        for ridx in np.argsort(np.where(live, heat, -1.0))[::-1]:
            ridx = int(ridx)
            if budget <= 0 or heat[ridx] <= cfg.split_factor * mean:
                break
            if controller.free_slots() == 0:
                break  # pool exhausted: shape stability outranks splitting
            lo, hi = controller.range_span(ridx)
            if hi - lo + 1 < cfg.min_split_span:
                continue
            boundary = _sketch_boundary(lo, hi, report)
            if boundary is None:
                boundary = lo + (hi - lo) // 2
            child = controller.split_range(ridx, boundary)
            if child is None:
                continue
            self._cool.pop(child, None)
            budget -= 1

        # ---- merges: children cool for `merge_patience` straight reports
        threshold = cfg.merge_factor * mean
        for child in controller.children():
            if report.live is not None and not report.live[child]:
                # born after the report snapshot (e.g. by the split pass
                # above): its zero heat is ignorance, not coolness — a
                # spurious tick here would halve the hysteresis
                continue
            if heat[child] < threshold:
                self._cool[child] = self._cool.get(child, 0) + 1
            else:
                self._cool[child] = 0
            if self._cool.get(child, 0) >= cfg.merge_patience:
                merged = controller.merge_range(child)
                if merged is not None:
                    ops.extend(merged)
                    self._cool.pop(child, None)
        # drop hysteresis state for slots that died some other way
        live_children = set(controller.children())
        for s in list(self._cool):
            if s not in live_children:
                self._cool.pop(s)

        # lineage upkeep (opt-in): merges can orphan grandchildren (their
        # parent slot died or was reused) and adversarial split runs
        # deepen the lineage; re-parenting onto adjacent live slots keeps
        # every child mergeable and bounds `generation` depth
        if cfg.max_lineage_depth is not None:
            controller.compact_lineage(cfg.max_lineage_depth)
        return ops


class SplitHotPolicy(_SplitMergeMixin, Policy):
    """Hot-subset splitting + migration (the slot-pool showcase).

    Against ``migrate`` this moves strictly less data for the same
    imbalance reduction: the balancer's hottest-range pick lands on a
    split child whose span covers only the hot subset, so the emitted
    move op is priced by the hot keys, not the whole range's residents.
    """

    name = "split_hot"

    def on_report(self, controller, report):
        ops = self.split_merge(controller, report)
        ops.extend(controller.balance(report))
        return ops


class ReplicatePolicy(Policy):
    """Hot-range selective replication + load-aware read spreading.

    Widens the chains of ranges whose *per-replica* heat dominates the
    mean — possibly by several replicas in one round — and narrows cooled
    chains back to the base replication.  Declares ``read_spread``
    because widening without spreading is pointless: tail-only reads
    would simply all move to the newcomer.

    Two details matter in practice (found the hard way):

    * consecutive widenings must account for the load they just shifted —
      picking "the coldest node" from a stale report piles every new
      replica onto the same three nodes and simply relocates the hotspot;
    * widened members are lazily-refreshed *read replicas*: the write's
      client-visible path stays the base chain (``plan_hops
      write_chain_cap``), and this policy re-emits a refresh copy per
      standing widened replica each round — the sync traffic the bench
      charges as migration bytes.
    """

    name = "replicate"
    read_spread = True

    def on_report(self, controller, report):
        cfg = self.config
        heat, live, mean = _live_heat(controller, report)
        ops: list[MigrationOp] = []
        if mean <= 0:
            return ops
        nl = report.node_load.astype(np.float64).copy()
        clen = controller.chain_lengths().astype(np.float64)
        # cadence-aware widen budget (1.0 scale on fixed cadence)
        budget = max(1, int(round(cfg.max_widen_per_round
                                  * report.budget_scale)))

        # hottest per live replica first: a wide warm chain is already
        # fine; dead slots and fully-spliced chains (clen 0) carry no
        # replica to widen from and are masked out
        ratio = np.where(live & (clen > 0), heat / np.maximum(clen, 1.0), -1.0)
        for ridx in np.argsort(ratio)[::-1]:
            if budget <= 0 or ratio[ridx] <= 0:
                break
            while budget > 0 and heat[ridx] / clen[ridx] > cfg.hot_factor * mean:
                op = controller.widen_chain(int(ridx), nl)
                if op is None:
                    break
                ops.append(op)
                budget -= 1
                # re-estimate: members shed read share, newcomer takes one
                c = clen[ridx]
                for m in controller.chain_nodes(int(ridx))[: int(c)]:
                    nl[int(m)] -= heat[ridx] / (c * (c + 1))
                nl[op.dst] += heat[ridx] / (c + 1)
                clen[ridx] += 1

        cl = controller.chain_lengths()
        widened = live & (cl > cfg.base_replication)
        if cfg.narrow_below_mean:
            for ridx in np.where(widened)[0]:
                if heat[ridx] < mean:
                    op = controller.narrow_chain(int(ridx), cfg.base_replication)
                    if op is not None:
                        ops.append(op)
            cl = controller.chain_lengths()
            widened = live & (cl > cfg.base_replication)

        # periodic refresh of standing read replicas (lazy delta sync)
        for ridx in np.where(widened)[0]:
            lo, hi = controller.range_span(int(ridx))
            chain = controller.chain_nodes(int(ridx))
            head = int(chain[0])
            for pos in range(cfg.base_replication, int(cl[ridx])):
                dst = int(chain[pos])
                if dst >= 0 and not any(
                    o.kind == "copy" and o.dst == dst and o.lo == lo
                    for o in ops
                ):
                    ops.append(MigrationOp(lo=lo, hi=hi, src=head, dst=dst,
                                           kind="copy"))
        return ops


class FullAdaptivePolicy(_SplitMergeMixin, ReplicatePolicy):
    """Everything on: split/merge + replicate + spread + migrate.

    Splitting isolates the hot subset of a range; replication handles
    subsets too hot for any single tail; migration evens out the residual
    per-node imbalance the replicas leave behind; the merge hysteresis
    re-coalesces split records once their heat subsides.
    """

    name = "full_adaptive"

    def on_report(self, controller, report):
        ops = self.split_merge(controller, report)
        ops.extend(super().on_report(controller, report))
        ops.extend(controller.balance(report))
        return ops


class OverloadAdaptivePolicy(FullAdaptivePolicy):
    """Everything on, plus the survival layer (repro.overload):

    * **AIMD admission control** — queue occupancy above ``admit_hi``
      multiplicatively cuts that node's admission probability (explicit
      client backpressure instead of queue collapse); occupancy below
      ``admit_lo`` additively recovers it toward 1.0, with a floor so
      recovery is always probed;
    * **retry budgeting** — released backoff retries are capped at
      ``retry_frac`` of the service rate per node per epoch, so a
      synchronized backlog release (the retry storm) cannot re-overrun
      the queues it just drained;
    * **capacity autoscale** — mean occupancy over serving nodes above
      ``scale_up_util`` (or any standing retry backlog) for
      ``scale_patience`` straight reports activates a standby node
      (``Controller.activate_node``); occupancy below ``scale_down_util``
      with an empty backlog parks the least-loaded node back into the
      reserve (``Controller.park_node`` — its repair-copy drain rides the
      returned migration plan, journaled through ``repl_log``).

    The control channel is attribute-based: the epoch driver grafts
    ``admit_prob`` / ``retry_budget`` onto the device registers after
    each report and drains ``notes`` into the epoch's event log.  Without
    an overload plane (``queue_limit == 0``) this is exactly
    ``full_adaptive``.
    """

    name = "overload_adaptive"

    def __init__(self, config: PolicyConfig | None = None):
        super().__init__(config)
        self.admit_prob: np.ndarray | None = None
        self.retry_budget: np.ndarray | None = None
        self.notes: list[str] = []
        self._hi_rounds = 0
        self._lo_rounds = 0

    def on_report(self, controller, report):
        ops = super().on_report(controller, report)
        ops.extend(self._backpressure(controller, report))
        return ops

    def _backpressure(self, controller: Controller, report: StatsReport
                      ) -> list[MigrationOp]:
        cfg = self.config
        if report.queue_limit <= 0 or report.queue_depth is None:
            return []
        N = report.node_load.shape[0]
        # pressure signal: post-drain queue depth alone understates a
        # node in trouble (a full queue that drains service_rate looks
        # calm), so fold in its retry backlog — queries the node already
        # turned away that are coming back
        rb = (report.retry_backlog.astype(np.float64)
              if report.retry_backlog is not None
              else np.zeros(report.queue_depth.shape[0]))
        occ = ((report.queue_depth.astype(np.float64) + rb)
               / float(report.queue_limit))
        ap = (self.admit_prob if self.admit_prob is not None
              else np.ones(N, np.float64))
        ap = np.where(
            occ > cfg.admit_hi, ap * cfg.admit_decrease,
            np.where(occ < cfg.admit_lo,
                     np.minimum(ap + cfg.admit_increase, 1.0), ap),
        )
        self.admit_prob = np.clip(ap, cfg.admit_floor, 1.0)
        self.retry_budget = np.full(
            N, max(1, int(cfg.retry_frac * report.service_limit)), np.int64
        )

        # ---- autoscale: band + patience on serving-node occupancy ----
        serving = controller.live_nodes()
        util = float(occ[serving].mean()) if serving else 0.0
        backlog = (int(report.retry_backlog.sum())
                   if report.retry_backlog is not None else 0)
        if util > cfg.scale_up_util or backlog > 0:
            self._hi_rounds += 1
            self._lo_rounds = 0
        elif util < cfg.scale_down_util and backlog == 0:
            self._lo_rounds += 1
            self._hi_rounds = 0
        else:
            self._hi_rounds = self._lo_rounds = 0

        ops: list[MigrationOp] = []
        if self._hi_rounds >= cfg.scale_patience and controller.standby:
            node = min(controller.standby)
            controller.activate_node(node)
            self.notes.append(f"autoscale_up:{node}")
            self._hi_rounds = 0
        elif (self._lo_rounds >= cfg.scale_patience
              and len(serving) - 1 >= max(cfg.min_serving,
                                          cfg.base_replication)):
            node = min(serving, key=lambda n: report.node_load[n])
            ops.extend(controller.park_node(node, report.node_load))
            self.notes.append(f"autoscale_down:{node}")
            self._lo_rounds = 0
        return ops


POLICIES = {
    "frozen": Policy,
    "migrate": MigratePolicy,
    "replicate": ReplicatePolicy,
    "split_hot": SplitHotPolicy,
    "full_adaptive": FullAdaptivePolicy,
    "overload_adaptive": OverloadAdaptivePolicy,
}


def make_policy(name: str, config: PolicyConfig | None = None) -> Policy:
    if name not in POLICIES:
        raise ValueError(f"unknown policy {name!r}; pick from {sorted(POLICIES)}")
    return POLICIES[name](config)

"""Per-epoch cluster metrics (the monitoring half of paper §5.1).

The closed loop needs numbers on both sides: the *data plane* produces
per-epoch load/latency observations, the *bench* consumes per-run
summaries comparing policies.  Everything here is plain numpy — these are
control-plane/reporting quantities, deliberately off the jitted step.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import keys as K
from repro.core.migration import MigrationOp
from repro.core.store import StoreState


@dataclasses.dataclass
class EpochMetrics:
    """One epoch's observation row (JSON-serializable via ``to_row``)."""

    epoch: int
    scenario: str
    policy: str
    ops: int                  # ops injected this epoch
    throughput: float         # ops / DES makespan (ops per tick)
    p50: float                # DES closed-loop latency percentiles (ticks)
    p99: float
    makespan: float
    imbalance: float          # max/mean per-node ops over live nodes
    cov: float                # coefficient of variation of per-node ops
    migration_entries: int    # entries moved/copied by control ops this epoch
    migration_bytes: int      # wire estimate of the above
    drops: int                # store capacity drops (overflow delta)
    retries: int              # bucket overflows (dist backend; 0 for oracle)
    compiled_steps: int       # cumulative device-step trace count
    events: list[str] = dataclasses.field(default_factory=list)
    # ---- overload observables (repro.overload; all 0 when disabled) ----
    deferred: int = 0         # admission-gated queries (client backpressure)
    shed: int = 0             # queue-full rejections entering retry orbit
    requeued: int = 0         # backoff retries re-admitted this epoch
    lost: int = 0             # retries escaping past the top backoff level
    queue_peak: int = 0       # max per-node queue occupancy after the epoch
    # ---- replication-mode observables (repro.replication) ----
    p999: float = 0.0         # extreme tail (p99.9) over all ops
    read_p99: float = 0.0     # p99 over GET/SCAN ops only
    clean_read_p99: float = 0.0   # p99 over reads served WITHOUT a CRAQ
                                  # tail bounce (== read_p99 off-craq)
    dirty_reads: int = 0      # reads that bounced to the tail this epoch
    replication: str = "eventual"
    # ---- coordination-tier observables (repro.coordination_tier) ----
    # exact conservation holds per row: routed == direct + redirected
    routed: int = 0           # queries resolved through the switch tier
    direct: int = 0           # served off a non-divergent table row
    redirected: int = 0       # versioned redirects (one priced extra hop)
    mis_served: int = 0       # stale wrong-owner serves NOT redirected
    stale_switches: int = 0   # switch copies divergent at epoch end
    coordination: str = "none"

    def to_row(self) -> dict:
        row = dataclasses.asdict(self)
        row["events"] = list(self.events)
        return row

    @classmethod
    def from_row(cls, row: dict) -> "EpochMetrics":
        """Inverse of :func:`to_row`: rebuild the dataclass from its JSON
        dict (round-trip asserted in ``tests/test_cluster.py`` — bench
        artifacts must reconstruct without loss)."""
        return cls(**{**row, "events": list(row.get("events", []))})


def latency_percentiles(latency: np.ndarray) -> tuple[float, float]:
    """(p50, p99) of a DES latency vector."""
    lat = np.asarray(latency, np.float64)
    if lat.size == 0:
        return 0.0, 0.0
    return float(np.percentile(lat, 50)), float(np.percentile(lat, 99))


def latency_percentiles_batch(latency: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Per-epoch (p50s, p99s) over a period's stacked (P, B) latency matrix
    — one vectorized percentile pass; each row's result is exactly what
    :func:`latency_percentiles` computes on that row alone."""
    lat = np.asarray(latency, np.float64)
    if lat.ndim != 2:
        raise ValueError(f"expected (P, B) latency, got shape {lat.shape}")
    if lat.shape[1] == 0:
        z = np.zeros(lat.shape[0])
        return z, z.copy()
    qs = np.percentile(lat, (50, 99), axis=1)
    return qs[0], qs[1]


def p999_batch(latency: np.ndarray) -> np.ndarray:
    """Per-epoch p99.9 over a (P, B) latency matrix — the extreme-tail
    column of the replication-mode comparison (coordination overheads and
    tail bounces live out there)."""
    lat = np.asarray(latency, np.float64)
    if lat.ndim != 2:
        raise ValueError(f"expected (P, B) latency, got shape {lat.shape}")
    if lat.shape[1] == 0:
        return np.zeros(lat.shape[0])
    return np.percentile(lat, 99.9, axis=1)


def masked_p99_batch(latency: np.ndarray, mask: np.ndarray) -> np.ndarray:
    """Per-epoch p99 over the masked entries of a (P, B) latency matrix
    (e.g. reads only, or clean reads only).  Rows whose mask is empty
    report 0.0.

    One sort-based pass over the whole matrix: masked-out entries are
    padded to +inf so each row's live values sort to the front, then the
    per-row 0.99 rank is interpolated exactly as ``np.percentile`` does
    (same floor/ceil gather, same ``_lerp`` formula — including its
    ``t >= 0.5`` branch, which differs from a naive ``a + diff*t`` in the
    last ulp).  Bit-identical to the per-row loop it replaced, kept as
    :func:`masked_p99_batch_loop` for the equivalence test."""
    lat = np.asarray(latency, np.float64)
    m = np.asarray(mask, bool)
    if lat.shape != m.shape or lat.ndim != 2:
        raise ValueError(f"latency {lat.shape} vs mask {m.shape}")
    P, B = lat.shape
    if B == 0:
        return np.zeros(P)
    padded = np.where(m, lat, np.inf)
    padded.sort(axis=1)
    n = m.sum(axis=1)                       # live count per row
    ok = n > 0
    vi = 0.99 * (np.where(ok, n, 1) - 1)    # virtual index, guarded
    lo = np.floor(vi).astype(np.intp)
    hi = np.ceil(vi).astype(np.intp)
    a = np.take_along_axis(padded, lo[:, None], axis=1)[:, 0]
    b = np.take_along_axis(padded, hi[:, None], axis=1)[:, 0]
    # zero empty rows BEFORE the arithmetic: their pad is +inf and
    # inf - inf would raise a warning on lanes we discard anyway
    a = np.where(ok, a, 0.0)
    b = np.where(ok, b, 0.0)
    t = vi - lo
    diff = b - a
    return np.where(t >= 0.5, b - diff * (1 - t), a + diff * t)


def masked_p99_batch_loop(latency: np.ndarray, mask: np.ndarray) -> np.ndarray:
    """The per-row reference implementation of :func:`masked_p99_batch`
    (one ``np.percentile`` call per epoch row) — the equivalence oracle."""
    lat = np.asarray(latency, np.float64)
    m = np.asarray(mask, bool)
    if lat.shape != m.shape or lat.ndim != 2:
        raise ValueError(f"latency {lat.shape} vs mask {m.shape}")
    out = np.zeros(lat.shape[0])
    for i in range(lat.shape[0]):
        row = lat[i][m[i]]
        if row.size:
            out[i] = np.percentile(row, 99)
    return out


def imbalance_stats_batch(node_ops: np.ndarray, live: np.ndarray | None = None
                          ) -> tuple[np.ndarray, np.ndarray]:
    """Per-epoch (max/mean, CoV) over a period's stacked (P, N) node-ops
    matrix; row-identical to :func:`imbalance_stats` (the node liveness
    mask is constant within a period — control events only fire at
    segment boundaries)."""
    ops = np.asarray(node_ops, np.float64)
    if ops.ndim != 2:
        raise ValueError(f"expected (P, N) node_ops, got shape {ops.shape}")
    if live is not None:
        ops = ops[:, np.asarray(live, bool)]
    P = ops.shape[0]
    if ops.shape[1] == 0:
        return np.ones(P), np.zeros(P)
    mean = ops.mean(axis=1)
    ok = mean > 0
    safe = np.where(ok, mean, 1.0)
    imb = np.where(ok, ops.max(axis=1) / safe, 1.0)
    cov = np.where(ok, ops.std(axis=1) / safe, 0.0)
    return imb, cov


def imbalance_stats(node_ops: np.ndarray, live: np.ndarray | None = None
                    ) -> tuple[float, float]:
    """(max/mean, CoV) of per-node served ops, over live nodes only.

    max/mean is the paper's balancing trigger quantity
    (``ControllerConfig.imbalance_threshold`` compares against it); CoV
    adds a whole-distribution view that max/mean misses.
    """
    ops = np.asarray(node_ops, np.float64)
    if live is not None:
        ops = ops[np.asarray(live, bool)]
    mean = ops.mean() if ops.size else 0.0
    if mean <= 0:
        return 1.0, 0.0
    return float(ops.max() / mean), float(ops.std() / mean)


def migration_traffic(store: StoreState, ops: list[MigrationOp],
                      value_dim: int) -> tuple[int, int]:
    """(entries, bytes) a migration plan will move, counted on the source.

    Counts actual resident entries in each op's [lo, hi] span on its
    source shard *before* execution — the directory-span estimate the
    controller reasons with can be badly off under skew.  Bytes model the
    shim wire format: 4-byte key + value_dim f32 words.
    """
    keys = np.asarray(store.keys)
    entries = 0
    for op in ops:
        if op.kind == "reclaim":
            continue  # no data moves; space is reclaimed in place
        slab = keys[op.src]
        empty = np.uint32(K.EMPTY_KEY)
        entries += int(
            ((slab >= op.lo) & (slab <= op.hi) & (slab != empty)).sum()
        )
    return entries, entries * 4 * (1 + value_dim)


def summarize(rows: list[EpochMetrics]) -> dict:
    """Aggregate a run's epoch rows into the bench comparison row."""
    if not rows:
        return {}
    f = lambda k: np.asarray([getattr(r, k) for r in rows], np.float64)
    return {
        "scenario": rows[0].scenario,
        "policy": rows[0].policy,
        "replication": rows[0].replication,
        "coordination": rows[0].coordination,
        "epochs": len(rows),
        "mean_throughput": float(f("throughput").mean()),
        "mean_p50": float(f("p50").mean()),
        "mean_p99": float(f("p99").mean()),
        "max_p99": float(f("p99").max()),
        "mean_p999": float(f("p999").mean()),
        "max_p999": float(f("p999").max()),
        "mean_read_p99": float(f("read_p99").mean()),
        "mean_clean_read_p99": float(f("clean_read_p99").mean()),
        "total_dirty_reads": int(f("dirty_reads").sum()),
        "mean_imbalance": float(f("imbalance").mean()),
        "max_imbalance": float(f("imbalance").max()),
        "mean_cov": float(f("cov").mean()),
        "total_migration_entries": int(f("migration_entries").sum()),
        "total_migration_bytes": int(f("migration_bytes").sum()),
        "total_drops": int(f("drops").sum()),
        "total_retries": int(f("retries").sum()),
        "total_deferred": int(f("deferred").sum()),
        "total_shed": int(f("shed").sum()),
        "total_requeued": int(f("requeued").sum()),
        "total_lost": int(f("lost").sum()),
        "max_queue_peak": int(f("queue_peak").max()),
        "total_routed": int(f("routed").sum()),
        "total_direct": int(f("direct").sum()),
        "total_redirected": int(f("redirected").sum()),
        "total_mis_served": int(f("mis_served").sum()),
        "max_stale_switches": int(f("stale_switches").max()),
        "compiled_steps": int(rows[-1].compiled_steps),
    }

"""repro.cluster — the closed-loop adaptive-balancing subsystem (§5.1).

Turns the existing parts (switch routing + statistics, controller,
migration movers, DES engine) into the paper's actual *system*: a cluster
that watches its own in-switch counters under a live, time-varying
workload and rebalances itself.

    scenario --epoch batches--> EpochDriver (fused jitted device step)
        |                           |
        |   StatsReport / sketch    v
        policy (migrate / replicate / spread) --MigrationOps--> movers
        ^                           |
        +------ Controller.refresh -+   (counters survive; shapes frozen)

Entry points: :class:`~repro.cluster.epoch.EpochDriver`,
:func:`~repro.cluster.scenarios.make_scenario`,
:func:`~repro.cluster.policies.make_policy`.
"""

from repro.cluster.epoch import ClusterConfig, EpochDriver
from repro.cluster.metrics import (
    EpochMetrics,
    imbalance_stats,
    imbalance_stats_batch,
    latency_percentiles,
    latency_percentiles_batch,
    masked_p99_batch,
    masked_p99_batch_loop,
    p999_batch,
    summarize,
)
from repro.coordination_tier import CoordConfig
from repro.telemetry import SLO, MetricsConfig, TelemetryConfig
from repro.cluster.policies import (
    POLICIES,
    FullAdaptivePolicy,
    MigratePolicy,
    OverloadAdaptivePolicy,
    Policy,
    PolicyConfig,
    ReplicatePolicy,
    make_policy,
)
from repro.cluster.scenarios import SCENARIOS, Scenario, ScenarioConfig, make_scenario

__all__ = [
    "ClusterConfig", "EpochDriver",
    "EpochMetrics", "imbalance_stats", "imbalance_stats_batch",
    "latency_percentiles", "latency_percentiles_batch",
    "masked_p99_batch", "masked_p99_batch_loop", "p999_batch", "summarize",
    "CoordConfig", "TelemetryConfig", "MetricsConfig", "SLO",
    "POLICIES", "Policy", "PolicyConfig", "MigratePolicy", "ReplicatePolicy",
    "FullAdaptivePolicy", "OverloadAdaptivePolicy", "make_policy",
    "SCENARIOS", "Scenario", "ScenarioConfig", "make_scenario",
]

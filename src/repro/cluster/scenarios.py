"""Time-varying workload scenarios over :mod:`repro.data.ycsb`.

The paper evaluates static YCSB mixes; the adaptive-balancing loop only
earns its keep when the workload *moves*.  Each scenario emits one
fixed-shape op batch per epoch (shapes never change within a scenario, so
the cluster epoch step compiles exactly once) plus a control-event stream
(node failures/recoveries) the driver feeds to the controller.

Scenario zoo:

* ``shifting_hotspot`` — Zipf heat whose hot block rotates through the
  sorted key space (the headline adaptive-balancing stressor; the bench
  acceptance gate runs this at theta=1.2).
* ``flash_crowd``     — uniform background, then a tiny key block takes a
  large traffic share for a few epochs and vanishes again.
* ``diurnal``         — fixed Zipf popularity, sinusoidal read/write mix
  (day: read-heavy; night: write-heavy).
* ``node_failure``    — steady skewed load with a storage-node failure
  mid-run (and optional recovery) — §5.2 meets §5.1.
* ``multi_hotspot``   — several simultaneous Zipf hotspots on distinct
  key blocks, rotating over the run: whole-range control wastes motion on
  the cold remainder of each hot range, hot-subset splitting pays — the
  showcase workload for the slot-pool directory.
* ``keyspace_growth`` — insert-driven occupancy growth: only a prefix of
  the record set exists at load time and the active frontier (where both
  inserts and reads concentrate) climbs through the key space, shifting
  range occupancy against the static genesis bounds.
* ``rack_failure_hotspot`` — correlated failure: a whole rack (= the
  switch fronting it, paper §5.2) dies mid-run while a Zipf hotspot is
  rotating through the key space — the two PR-2 stressors composed, so
  the splice-the-whole-rack path is exercised by the scenario library,
  not just unit tests.
* ``ycsb_a``          — the classic update-heavy 50/50 mix (YCSB
  workload A) over stationary Zipf heat: the write-path stressor the
  replication-mode comparison (``repro.replication``) runs — chain-mode
  write broadcasts and CRAQ dirty windows both scale with the update
  share, which the read-heavy default mixes barely exercise.
* ``cascade_failure`` — overload stressor: a whole rack dies mid-run
  while the offered load stays constant, so the survivors inherit the
  dead rack's traffic on top of their own.  Without admission control
  the survivor queues collapse (service inflation compounds the
  backlog); with ``repro.overload`` + standby activation the cluster
  sheds, backs off, and recruits spare capacity instead.
* ``retry_storm``     — overload stressor: a rack blinks out and comes
  back a few epochs later.  Every query shed during the outage re-fires
  on its backoff schedule, so recovery is greeted by a synchronized
  retry wave on top of fresh load — the classic thundering-herd /
  metastable-failure shape bounded backoff budgets exist to break.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import keys as K
from repro.data.ycsb import _zipf_probs


@dataclasses.dataclass(frozen=True)
class ScenarioConfig:
    """Shared scenario knobs (fixed shapes: epoch_ops × n_epochs)."""

    n_epochs: int = 12
    epoch_ops: int = 2048
    n_records: int = 4096
    value_dim: int = 8
    read_ratio: float = 0.9       # base mix; diurnal modulates it
    seed: int = 0


class Scenario:
    """Base: stationary Zipf workload (subclasses add time variation)."""

    name = "stationary"

    def __init__(self, cfg: ScenarioConfig, *, theta: float = 0.99):
        self.cfg = cfg
        self.theta = theta
        rng = np.random.default_rng(cfg.seed)
        # distinct sorted record keys spread over the key space (ycsb idiom)
        self.record_keys = np.sort(
            rng.choice(np.uint64(K.KEY_SPACE - 2), size=cfg.n_records,
                       replace=False).astype(np.uint32)
        )
        self.base_probs = _zipf_probs(cfg.n_records, theta)
        # scatter heat over the key space for the stationary base case
        self.perm = rng.permutation(cfg.n_records)

    # -- per-epoch knobs subclasses override -------------------------------
    def record_probs(self, epoch: int) -> np.ndarray:
        """Popularity over record *indices* (sorted-key order) this epoch."""
        p = np.empty_like(self.base_probs)
        p[self.perm] = self.base_probs
        return p

    def read_ratio(self, epoch: int) -> float:
        return self.cfg.read_ratio

    def events(self, epoch: int) -> list[tuple[str, int]]:
        """Control events fired at the *start* of this epoch."""
        return []

    # -- generation --------------------------------------------------------
    def load(self):
        """(keys, values) preloaded before epoch 0 (YCSB load phase)."""
        rng = np.random.default_rng(self.cfg.seed + 1)
        vals = rng.normal(size=(self.cfg.n_records, self.cfg.value_dim))
        return self.record_keys, vals.astype(np.float32)

    def epoch(self, e: int):
        """One epoch's op stream: (opcodes, keys, end_keys, values)."""
        cfg = self.cfg
        rng = np.random.default_rng(cfg.seed + 100 + e)
        idx = rng.choice(cfg.n_records, size=cfg.epoch_ops,
                         p=self.record_probs(e))
        keys = self.record_keys[idx]
        r = self.read_ratio(e)
        opcodes = np.where(rng.random(cfg.epoch_ops) < r, K.OP_GET,
                           K.OP_PUT).astype(np.int32)
        end_keys = np.zeros(cfg.epoch_ops, np.uint32)
        values = rng.normal(size=(cfg.epoch_ops, cfg.value_dim)).astype(np.float32)
        return opcodes, keys, end_keys, values


class ShiftingHotspot(Scenario):
    """Zipf heat concentrated on a contiguous sorted-key block that jumps
    to a new quarter of the key space every ``shift_every`` epochs.

    Contiguous in sorted-key order == contiguous sub-ranges == a few hot
    chains — the worst case for a frozen directory and the best case for
    migration + selective replication.
    """

    name = "shifting_hotspot"

    def __init__(self, cfg: ScenarioConfig, *, theta: float = 1.2,
                 shift_every: int = 3):
        super().__init__(cfg, theta=theta)
        self.shift_every = shift_every

    def record_probs(self, epoch: int) -> np.ndarray:
        n = self.cfg.n_records
        start = ((epoch // self.shift_every) * (n // 4)) % n
        # rank r (hottest first) -> record index (start + r) % n
        p = np.empty(n)
        ranks = (np.arange(n) + start) % n
        p[ranks] = self.base_probs
        return p


class FlashCrowd(Scenario):
    """Uniform background; epochs [t0, t1) send ``crowd_frac`` of traffic
    to a ``crowd_records``-wide contiguous key block."""

    name = "flash_crowd"

    def __init__(self, cfg: ScenarioConfig, *, t0: int = 4, t1: int = 8,
                 crowd_frac: float = 0.7, crowd_records: int = 32):
        super().__init__(cfg, theta=0.0)
        self.t0, self.t1 = t0, t1
        self.crowd_frac = crowd_frac
        self.crowd_records = min(crowd_records, cfg.n_records)

    def record_probs(self, epoch: int) -> np.ndarray:
        n = self.cfg.n_records
        p = np.full(n, 1.0 / n)
        if self.t0 <= epoch < self.t1:
            crowd = np.zeros(n)
            lo = (n // 2) % max(n - self.crowd_records, 1)
            crowd[lo:lo + self.crowd_records] = 1.0 / self.crowd_records
            p = (1 - self.crowd_frac) * p + self.crowd_frac * crowd
        return p / p.sum()


class Diurnal(Scenario):
    """Fixed Zipf heat; read ratio swings sinusoidally over the run
    (read-heavy 'day' to write-heavy 'night')."""

    name = "diurnal"

    def __init__(self, cfg: ScenarioConfig, *, theta: float = 0.9,
                 lo: float = 0.5, hi: float = 0.95, period: int | None = None):
        super().__init__(cfg, theta=theta)
        self.lo, self.hi = lo, hi
        self.period = period or cfg.n_epochs

    def read_ratio(self, epoch: int) -> float:
        phase = 2.0 * np.pi * epoch / max(self.period, 1)
        return self.lo + (self.hi - self.lo) * 0.5 * (1.0 + np.sin(phase))


class NodeFailure(Scenario):
    """Steady Zipf load with a node failure mid-run (optional recovery)."""

    name = "node_failure"

    def __init__(self, cfg: ScenarioConfig, *, theta: float = 0.99,
                 fail_epoch: int = 4, fail_node: int = 0,
                 recover_epoch: int | None = None):
        super().__init__(cfg, theta=theta)
        self.fail_epoch = fail_epoch
        self.fail_node = fail_node
        self.recover_epoch = recover_epoch

    def events(self, epoch: int) -> list[tuple[str, int]]:
        ev = []
        if epoch == self.fail_epoch:
            ev.append(("fail", self.fail_node))
        if self.recover_epoch is not None and epoch == self.recover_epoch:
            ev.append(("recover", self.fail_node))
        return ev


class MultiHotspot(Scenario):
    """``n_hotspots`` simultaneous Zipf hotspots on distinct contiguous
    key blocks, all rotating every ``shift_every`` epochs.

    Zipf rank r (hottest first) feeds hotspot ``r % k`` at within-block
    offset ``r // k``, so each block carries its own Zipf-decaying heat
    spike.  With k spikes alive at once there are not enough cold nodes
    to absorb whole-range moves — isolating the hot *subset* of each
    range (split, then act on the child) is the winning play.
    """

    name = "multi_hotspot"

    def __init__(self, cfg: ScenarioConfig, *, theta: float = 1.3,
                 n_hotspots: int = 3, shift_every: int = 4):
        super().__init__(cfg, theta=theta)
        self.n_hotspots = max(1, n_hotspots)
        self.shift_every = max(1, shift_every)
        # rotation stride: a quarter block per shift, so hotspots sweep
        # the space without immediately landing on each other
        self.stride = max(1, cfg.n_records // (4 * self.n_hotspots))

    def record_probs(self, epoch: int) -> np.ndarray:
        n = self.cfg.n_records
        k = self.n_hotspots
        shift = (epoch // self.shift_every) * self.stride
        r = np.arange(n)
        block = r % k                   # which hotspot this rank feeds
        offset = r // k                 # position inside the block
        pos = (block * (n // k) + shift + offset) % n
        p = np.zeros(n)
        np.add.at(p, pos, self.base_probs)
        return p / p.sum()


class KeyspaceGrowth(Scenario):
    """Insert-driven growth: only ``start_frac`` of the records exist at
    load time; each epoch the active frontier advances and traffic (write
    heavy, Zipf-concentrated on the newest records) follows it upward
    through the key space.  Static genesis bounds end up with a few
    overstuffed frontier ranges — occupancy pressure the split machinery
    relieves without touching the cold archive below.
    """

    name = "keyspace_growth"

    def __init__(self, cfg: ScenarioConfig, *, theta: float = 0.9,
                 start_frac: float = 0.25, write_ratio: float = 0.5):
        super().__init__(cfg, theta=theta)
        self.start_frac = min(max(start_frac, 0.01), 1.0)
        self.write_ratio = write_ratio

    def _active(self, epoch: int) -> int:
        n = self.cfg.n_records
        n0 = max(2, int(n * self.start_frac))
        grow = (n - n0) * (epoch + 1) // max(self.cfg.n_epochs, 1)
        return min(n, n0 + grow)

    def load(self):
        keys, vals = super().load()
        n0 = max(2, int(self.cfg.n_records * self.start_frac))
        return keys[:n0], vals[:n0]

    def record_probs(self, epoch: int) -> np.ndarray:
        n = self.cfg.n_records
        active = self._active(epoch)
        p = np.zeros(n)
        # newest records hottest: rank r -> record (active - 1 - r)
        p[active - 1 :: -1] = self.base_probs[:active]
        return p / p.sum()

    def read_ratio(self, epoch: int) -> float:
        return 1.0 - self.write_ratio


class YcsbA(Scenario):
    """YCSB workload A: ``update_ratio`` of ops are writes (default the
    canonical 50/50), Zipf-popular keys, stationary heat.  Write-heavy
    enough that replication write paths — not read spreading — set the
    tail: the headline mix for comparing ``eventual``/``chain``/``craq``.
    """

    name = "ycsb_a"

    def __init__(self, cfg: ScenarioConfig, *, theta: float = 0.99,
                 update_ratio: float = 0.5):
        super().__init__(cfg, theta=theta)
        self.update_ratio = min(max(update_ratio, 0.0), 1.0)

    def read_ratio(self, epoch: int) -> float:
        return 1.0 - self.update_ratio


class RackFailureHotspot(ShiftingHotspot):
    """Correlated failure under load: the Zipf hot block keeps rotating
    (as in ``shifting_hotspot``) and at ``fail_epoch`` a whole rack of
    storage nodes drops out at once — a switch failure takes down every
    node behind it (paper §5.2).  The driver routes the event through
    ``Controller.handle_switch_failure`` so all rack members are spliced
    *before* any chain is repaired (repair copies must never target a
    dead rack-mate).  Optional per-node recovery later in the run.
    """

    name = "rack_failure_hotspot"

    def __init__(self, cfg: ScenarioConfig, *, theta: float = 1.2,
                 shift_every: int = 3, fail_epoch: int = 4,
                 rack: tuple[int, ...] = (0, 1),
                 recover_epoch: int | None = None):
        super().__init__(cfg, theta=theta, shift_every=shift_every)
        self.fail_epoch = fail_epoch
        self.rack = tuple(int(n) for n in rack)
        self.recover_epoch = recover_epoch

    def events(self, epoch: int) -> list[tuple[str, object]]:
        ev: list[tuple[str, object]] = []
        if epoch == self.fail_epoch:
            ev.append(("rack_fail", self.rack))
        if self.recover_epoch is not None and epoch == self.recover_epoch:
            ev.extend(("recover", n) for n in self.rack)
        return ev


class CascadeFailure(Scenario):
    """Capacity-loss overload: stationary Zipf heat, constant offered
    load, and at ``fail_epoch`` a whole rack drops dead for the rest of
    the run.  The survivors must absorb the dead rack's share — offered
    load per live node jumps by ``N / (N - len(rack))`` — which drives
    queue occupancy (and with it the occupancy-dependent service
    inflation of ``repro.overload``) into the unstable regime unless the
    control plane sheds load and activates standby capacity.
    """

    name = "cascade_failure"

    def __init__(self, cfg: ScenarioConfig, *, theta: float = 0.9,
                 fail_epoch: int = 3, rack: tuple[int, ...] = (0, 1, 2)):
        super().__init__(cfg, theta=theta)
        self.fail_epoch = fail_epoch
        self.rack = tuple(int(n) for n in rack)

    def events(self, epoch: int) -> list[tuple[str, object]]:
        if epoch == self.fail_epoch:
            return [("rack_fail", self.rack)]
        return []


class RetryStorm(Scenario):
    """Transient outage + synchronized retries: a rack fails at
    ``fail_epoch`` and recovers at ``recover_epoch``.  Queries shed
    during the outage sit in the backoff orbit and re-arrive together
    once their timers expire — so the moment capacity returns, the
    cluster faces fresh load *plus* the accumulated retry wave.  An
    uncontrolled loop melts down exactly when it should be recovering
    (the metastable-failure signature); bounded retry budgets and
    admission probabilities let the wave drain instead of re-shedding
    into ever-higher backoff levels.
    """

    name = "retry_storm"

    def __init__(self, cfg: ScenarioConfig, *, theta: float = 0.9,
                 fail_epoch: int = 2, recover_epoch: int = 5,
                 rack: tuple[int, ...] = (0, 1)):
        super().__init__(cfg, theta=theta)
        self.fail_epoch = fail_epoch
        self.recover_epoch = recover_epoch
        self.rack = tuple(int(n) for n in rack)

    def events(self, epoch: int) -> list[tuple[str, object]]:
        ev: list[tuple[str, object]] = []
        if epoch == self.fail_epoch:
            ev.append(("rack_fail", self.rack))
        if epoch == self.recover_epoch:
            ev.extend(("recover", n) for n in self.rack)
        return ev


class LeaseExpiry(ShiftingHotspot):
    """Coordination-tier stressor: the controller's directory lease on the
    switch fabric expires mid-run while the Zipf hot block keeps rotating
    (so migrations keep rewriting the tables).  Staging stalls — committed
    versions run ahead of every switch copy, widening the stale window —
    until either an explicit renewal or the failover grace elapses and
    leadership moves down the switch chain
    (``repro.coordination_tier.CoordManager``).  Without the tier the
    events are ignored: the same scenario is the no-coordination baseline.
    """

    name = "lease_expiry"

    def __init__(self, cfg: ScenarioConfig, *, theta: float = 1.2,
                 shift_every: int = 3, expire_epoch: int = 3,
                 renew_epoch: int | None = None):
        super().__init__(cfg, theta=theta, shift_every=shift_every)
        self.expire_epoch = expire_epoch
        self.renew_epoch = renew_epoch

    def events(self, epoch: int) -> list[tuple[str, object]]:
        ev: list[tuple[str, object]] = []
        if epoch == self.expire_epoch:
            ev.append(("lease_expire", 0))
        if self.renew_epoch is not None and epoch == self.renew_epoch:
            ev.append(("lease_renew", 0))
        return ev


class SplitBrain(ShiftingHotspot):
    """Coordination-tier stressor: at ``split_epoch`` one switch partitions
    away from the quorum, claims leadership, and installs a divergent
    table (chain ownership rotated by one node, versions self-stamped past
    the commit).  Every query entering through the rogue switch would be
    served by the wrong owner; the versioned-redirect check catches the
    divergence and bounces them to the true owner instead.  Healing
    re-registers the rogue at the committed table.
    """

    name = "split_brain"

    def __init__(self, cfg: ScenarioConfig, *, theta: float = 1.2,
                 shift_every: int = 3, split_epoch: int = 3,
                 heal_epoch: int | None = 8, switch: int = 1):
        super().__init__(cfg, theta=theta, shift_every=shift_every)
        self.split_epoch = split_epoch
        self.heal_epoch = heal_epoch
        self.switch = int(switch)

    def events(self, epoch: int) -> list[tuple[str, object]]:
        ev: list[tuple[str, object]] = []
        if epoch == self.split_epoch:
            ev.append(("split_brain", self.switch))
        if self.heal_epoch is not None and epoch == self.heal_epoch:
            ev.append(("heal_split", self.switch))
        return ev


class QuorumDrift(ShiftingHotspot):
    """Coordination-tier stressor: at ``drift_epoch`` one switch's install
    lag multiplies (a congested control channel), so its table copy trails
    the quorum commit by ``drift_mult`` times the configured per-hop lag —
    every reconfiguration after that point leaves the drifted switch
    serving stale routes (and redirecting, under quorum reads) for a
    proportionally longer window.
    """

    name = "quorum_drift"

    def __init__(self, cfg: ScenarioConfig, *, theta: float = 1.2,
                 shift_every: int = 3, drift_epoch: int = 2,
                 switch: int = 2):
        super().__init__(cfg, theta=theta, shift_every=shift_every)
        self.drift_epoch = drift_epoch
        self.switch = int(switch)

    def events(self, epoch: int) -> list[tuple[str, object]]:
        if epoch == self.drift_epoch:
            return [("quorum_drift", self.switch)]
        return []


SCENARIOS = {
    "stationary": Scenario,
    "shifting_hotspot": ShiftingHotspot,
    "flash_crowd": FlashCrowd,
    "diurnal": Diurnal,
    "node_failure": NodeFailure,
    "multi_hotspot": MultiHotspot,
    "keyspace_growth": KeyspaceGrowth,
    "rack_failure_hotspot": RackFailureHotspot,
    "ycsb_a": YcsbA,
    "cascade_failure": CascadeFailure,
    "retry_storm": RetryStorm,
    "lease_expiry": LeaseExpiry,
    "split_brain": SplitBrain,
    "quorum_drift": QuorumDrift,
}


def make_scenario(name: str, cfg: ScenarioConfig | None = None, **kw) -> Scenario:
    if name not in SCENARIOS:
        raise ValueError(f"unknown scenario {name!r}; pick from {sorted(SCENARIOS)}")
    return SCENARIOS[name](cfg or ScenarioConfig(), **kw)

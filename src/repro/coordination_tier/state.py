"""Device-resident state for the replicated in-switch directory tier.

The paper's switches *are* the partition directory, but the cluster driver
historically modeled directory refresh as instant and global: one oracle
array the host grafts atomically (``Controller.refresh``), so a client
could never observe a lagging table.  This module promotes the directory
to a replicated per-switch service in the NetChain style (PAPERS.md): each
ToR/spine switch holds its own copy of the slot tables plus a per-slot
**version** register, and control writes propagate along the switch chain
with per-position lag — so after a split / migration / failure splice some
switches serve *stale* tables for a bounded window.

Representation (all shape-stable, carried and donated through the fused
period ``lax.scan`` exactly like the PR-5 ``ReplState`` register file):

``slot_lo / slot_hi / live / chains / chain_len``
    ``(W, S, ...)`` — switch ``w``'s private copy of the slot tables.
``version``
    ``(W, S) u32`` — the table version switch ``w`` believes slot ``s``
    is at.
``committed``
    ``(S,) u32`` — the quorum-committed version of each slot (the data
    plane's ground truth; bumped by the host controller the moment a
    control action rewrites a slot, *independent* of switch propagation).
``pend_* / install_at``
    staged next table: the full pending snapshot plus the epoch at which
    each switch installs it (``INSTALL_NEVER`` = nothing staged).  A slow
    switch whose pending is overwritten before it installed simply skips
    the intermediate version — exactly how a lagging replica catches up
    in NetChain (it syncs the latest state, not the edit log).

Stale routing is resolved *in-loop* and is accounting-plane only: the
query's TRUE routing decision (and therefore every store effect, counter,
and PRNG draw) is untouched; what staleness changes is the *path* — a
query entering a lagging switch follows the old table to the old server,
the server's version check detects the mismatch, and a versioned redirect
re-routes it (one extra hop, priced through the DES and counted in
telemetry's bounce bucket).  With the tier disabled, or with zero
propagation lag, the emitted metric stream is bit-identical to the
tier-less driver by construction.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import keys as K

# install_at sentinel: no staged table for this switch.
INSTALL_NEVER = np.int32(2**31 - 1)

# cstats vector layout (per-epoch coordination counters, all exact):
#   routed      — queries routed this epoch (== batch size)
#   direct      — served off a table row matching the committed version
#   redirected  — versioned redirect taken (extra hop priced in the DES)
#   mis_served  — served off a divergent wrong-owner row with NO redirect
#                 (only the no-quorum baseline can produce these)
#   stale_sw    — gauge: switches holding >=1 divergent slot this epoch
CSTAT_FIELDS = ("routed", "direct", "redirected", "mis_served", "stale_switches")


@dataclasses.dataclass(frozen=True)
class CoordConfig:
    """Knobs for the replicated directory tier.

    ``n_switches=None`` derives the tier width from the pod structure
    (``core.hierarchy.switch_topology``: one ToR per pod + one spine).
    ``lag_per_hop`` is the propagation delay (epochs) per chain position;
    0 makes every switch install at the staging epoch, which reproduces
    the tier-less metric stream bit-identically.  ``quorum=True`` is the
    lease + quorum-versioned arm (divergent rows are detected and
    redirected); ``False`` is the baseline that trusts whatever table the
    ingress switch holds.  ``staleness_bound=None`` derives the
    convergence bound as ``(W-1) * lag_per_hop * drift_mult``.
    """

    n_switches: int | None = 4
    lag_per_hop: int = 1
    quorum: bool = True
    staleness_bound: int | None = None
    lease_epochs: int = 4
    failover_after: int = 2
    drift_mult: int = 4


@partial(
    jax.tree_util.register_dataclass,
    data_fields=(
        "slot_lo",
        "slot_hi",
        "live",
        "chains",
        "chain_len",
        "version",
        "committed",
        "pend_lo",
        "pend_hi",
        "pend_live",
        "pend_chains",
        "pend_clen",
        "pend_version",
        "install_at",
    ),
    meta_fields=(),
)
@dataclasses.dataclass(frozen=True)
class CoordState:
    slot_lo: jnp.ndarray      # (W, S) u32
    slot_hi: jnp.ndarray      # (W, S) u32
    live: jnp.ndarray         # (W, S) bool
    chains: jnp.ndarray       # (W, S, r_max) i32
    chain_len: jnp.ndarray    # (W, S) i32
    version: jnp.ndarray      # (W, S) u32
    committed: jnp.ndarray    # (S,) u32
    pend_lo: jnp.ndarray      # (S,) u32
    pend_hi: jnp.ndarray      # (S,) u32
    pend_live: jnp.ndarray    # (S,) bool
    pend_chains: jnp.ndarray  # (S, r_max) i32
    pend_clen: jnp.ndarray    # (S,) i32
    pend_version: jnp.ndarray  # (S,) u32
    install_at: jnp.ndarray   # (W,) i32; INSTALL_NEVER = nothing staged

    @property
    def n_switches(self) -> int:
        return self.slot_lo.shape[0]

    @property
    def n_slots(self) -> int:
        return self.slot_lo.shape[1]


def make_state(tables: dict, n_switches: int) -> CoordState:
    """Fresh tier state: every switch holds ``tables`` at version 0.

    ``tables`` is a host snapshot (``Controller.table_snapshot()``).  All
    leaves are freshly materialized device arrays — nothing aliases the
    live directory, so the coord carry can be donated while the directory
    is not.
    """
    w = int(n_switches)
    lo = np.ascontiguousarray(tables["slot_lo"], np.uint32)
    hi = np.ascontiguousarray(tables["slot_hi"], np.uint32)
    lv = np.ascontiguousarray(tables["live"], bool)
    ch = np.ascontiguousarray(tables["chains"], np.int32)
    cl = np.ascontiguousarray(tables["chain_len"], np.int32)
    s = lo.shape[0]
    return CoordState(
        slot_lo=jnp.asarray(np.tile(lo[None], (w, 1))),
        slot_hi=jnp.asarray(np.tile(hi[None], (w, 1))),
        live=jnp.asarray(np.tile(lv[None], (w, 1))),
        chains=jnp.asarray(np.tile(ch[None], (w, 1, 1))),
        chain_len=jnp.asarray(np.tile(cl[None], (w, 1))),
        version=jnp.zeros((w, s), jnp.uint32),
        committed=jnp.zeros((s,), jnp.uint32),
        pend_lo=jnp.asarray(lo.copy()),
        pend_hi=jnp.asarray(hi.copy()),
        pend_live=jnp.asarray(lv.copy()),
        pend_chains=jnp.asarray(ch.copy()),
        pend_clen=jnp.asarray(cl.copy()),
        pend_version=jnp.zeros((s,), jnp.uint32),
        install_at=jnp.full((w,), INSTALL_NEVER, jnp.int32),
    )


def install_pending(state: CoordState, eid: jnp.ndarray) -> CoordState:
    """Switches whose install epoch has arrived adopt the staged table.

    Pure value rewrites at fixed shapes — runs at the top of every epoch
    inside the fused scan, so "install at epoch ``e``" means the table is
    visible to every query of epoch ``e``.
    """
    inst = eid.astype(jnp.int32) >= state.install_at  # (W,)

    def mix(tbl, pend):
        m = inst.reshape((-1,) + (1,) * (tbl.ndim - 1))
        return jnp.where(m, jnp.broadcast_to(pend[None], tbl.shape), tbl)

    return dataclasses.replace(
        state,
        slot_lo=mix(state.slot_lo, state.pend_lo),
        slot_hi=mix(state.slot_hi, state.pend_hi),
        live=mix(state.live, state.pend_live),
        chains=mix(state.chains, state.pend_chains),
        chain_len=mix(state.chain_len, state.pend_clen),
        version=mix(state.version, state.pend_version),
        install_at=jnp.where(inst, jnp.int32(INSTALL_NEVER), state.install_at),
    )


def ingress_switch(keys: jnp.ndarray, n_switches: int) -> jnp.ndarray:
    """Which switch a query enters the fabric through.

    Clients hash onto ToRs; the golden-hash mix keeps it deterministic
    (no PRNG consumed — the tier must not perturb the metric stream).
    """
    return (K.hash_key(keys) % jnp.uint32(n_switches)).astype(jnp.int32)


def stale_lookup(state: CoordState, sw: jnp.ndarray, mvals: jnp.ndarray) -> jnp.ndarray:
    """``directory.lookup_range`` evaluated against each query's *own
    switch's* table copy — bit-identical formula, per-query gathered rows.

    ``mvals`` is the matching value (``keys.matching_value``: hashed key
    under hash partitioning, the key itself under range partitioning) —
    the same header field the true lookup matches on, so a converged
    replica reproduces the oracle ridx exactly.  Dead slots carry the
    (DEAD_LO > DEAD_HI) sentinel in every replica, so they lose here
    exactly as in the oracle lookup.
    """
    lo = state.slot_lo[sw]    # (B, S)
    hi = state.slot_hi[sw]
    lv = state.live[sw]
    v = mvals.astype(jnp.uint32)[:, None]
    hit = lv & (v >= lo) & (v <= hi)
    s = lo.shape[1]
    idx = jnp.where(hit, jnp.arange(s, dtype=jnp.int32)[None, :], jnp.int32(s))
    ridx = jnp.min(idx, axis=1)
    return jnp.minimum(ridx, jnp.int32(s - 1))


def _chain_server(rows: jnp.ndarray, clen: jnp.ndarray, is_write: jnp.ndarray) -> jnp.ndarray:
    """Deterministic serving node under a table: chain head for writes,
    chain tail for reads (the version check happens at this node)."""
    head = rows[:, 0]
    last = jnp.maximum(clen - 1, 0)[:, None]
    tail = jnp.take_along_axis(rows, last, axis=1)[:, 0]
    return jnp.where(is_write, head, tail).astype(jnp.int32)


def observe_epoch(state, q, decision, eid, *, quorum: bool,
                  hash_partitioned: bool = False):
    """One epoch of the coordination tier: install staged tables, route
    each query through its ingress switch's (possibly stale) table, and
    resolve divergence.

    Returns ``(state', redirect, redirect_via, cstats)``:

    - ``redirect (B,) bool`` — the versioned redirect hop to take (quorum
      arm only; the baseline never redirects).
    - ``redirect_via (B,) i32`` — the stale server the query visits first
      (where the version check fires); priced as one extra lookup hop.
    - ``cstats (5,) i32`` — see ``CSTAT_FIELDS``; conservation
      ``routed == direct + redirected`` holds exactly by construction.

    The TRUE decision is computed by the unchanged routing path before
    this runs; store effects, counters and PRNG draws never depend on the
    tier — staleness only re-prices the path.  ``mis_served`` counts
    queries whose stale deterministic server differs from the true one
    and that were *not* redirected: wrong-owner service implies the slot
    row changed, which implies a version mismatch, so under the quorum
    arm this is zero by the divergence check.
    """
    state = install_pending(state, eid)
    w = state.n_switches

    is_write = (q.opcode == K.OP_PUT) | (q.opcode == K.OP_DEL)
    sw = ingress_switch(q.key, w)
    mv = K.matching_value(q.key, hash_partitioned=hash_partitioned)
    sridx = stale_lookup(state, sw, mv)

    via_stale = _chain_server(state.chains[sw, sridx], state.chain_len[sw, sridx], is_write)
    via_true = _chain_server(decision.chain, decision.chain_len, is_write)

    divergent = state.version[sw, sridx] != state.committed[sridx]
    wrong = via_stale != via_true
    if quorum:
        redirect = divergent
    else:
        redirect = jnp.zeros_like(divergent)
    mis = wrong & ~redirect
    redirect_via = jnp.where(via_stale >= 0, via_stale, via_true).astype(jnp.int32)

    routed = jnp.int32(q.key.shape[0])
    n_red = jnp.sum(redirect).astype(jnp.int32)
    stale_sw = jnp.sum(
        jnp.any(state.version != state.committed[None, :], axis=1)
    ).astype(jnp.int32)
    cstats = jnp.stack(
        [routed, routed - n_red, n_red, jnp.sum(mis).astype(jnp.int32), stale_sw]
    )
    return state, redirect, redirect_via, cstats


def empty_cstats() -> jnp.ndarray:
    """Counter vector when the tier is disabled (keeps scan ys uniform)."""
    return jnp.zeros((len(CSTAT_FIELDS),), jnp.int32)

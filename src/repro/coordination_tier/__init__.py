"""Switch-replicated directory tier: stale-table routing, versioned
redirects, leases, and split-brain survival (NetChain pattern over the
slot-pool directory — see state.py for the full design note)."""

from repro.coordination_tier.manager import EVENT_KINDS, CoordManager
from repro.coordination_tier.state import (
    CSTAT_FIELDS,
    INSTALL_NEVER,
    CoordConfig,
    CoordState,
    empty_cstats,
    ingress_switch,
    install_pending,
    make_state,
    observe_epoch,
    stale_lookup,
)

__all__ = [
    "CSTAT_FIELDS",
    "EVENT_KINDS",
    "INSTALL_NEVER",
    "CoordConfig",
    "CoordState",
    "CoordManager",
    "empty_cstats",
    "ingress_switch",
    "install_pending",
    "make_state",
    "observe_epoch",
    "stale_lookup",
]

"""Host-side control plane for the replicated directory tier.

The :class:`CoordManager` is the switch-chain controller: it diffs
successive host snapshots of the slot tables (``Controller.table_snapshot``
— never the live device directory, so no host syncs), bumps the
quorum-committed version of every slot a control action rewrote, and
*stages* the new table for propagation along the switch chain with
per-position lag.  It also owns the lease state machine (renewal at every
control pull; expiry stalls staging; failover moves leadership down the
chain after a grace window) and the fault injectors behind the
``lease_expiry`` / ``split_brain`` / ``quorum_drift`` scenarios.

Everything here runs between fused segments, exactly like the overload
plane's admit-probability grafts: the manager rewrites whole leaves of the
:class:`~repro.coordination_tier.state.CoordState` carry with freshly
materialized arrays of identical shape/dtype, so the compiled step never
retraces.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import hierarchy as H
from repro.coordination_tier import state as ST
from repro.coordination_tier.state import CoordConfig, CoordState, INSTALL_NEVER

_TABLE_KEYS = ("slot_lo", "slot_hi", "live", "chains", "chain_len")

# the scenario-event vocabulary :meth:`CoordManager.on_event` understands
# (the epoch driver routes exactly these kinds to the manager; a run
# without the tier ignores them, so fault scenarios double as the
# no-coordination baseline arm)
EVENT_KINDS = (
    "lease_expire",
    "lease_renew",
    "split_brain",
    "heal_split",
    "quorum_drift",
)


def _copy_tables(tables: dict) -> dict:
    return {k: np.array(tables[k]) for k in _TABLE_KEYS}


class CoordManager:
    """Lease-holding controller of the switch chain."""

    def __init__(self, cfg: CoordConfig, tables: dict, *, num_nodes: int, num_pods: int = 1):
        self.cfg = cfg
        self.chain = H.switch_topology(num_pods, cfg.n_switches)
        self.n_switches = len(self.chain)
        self.num_nodes = int(num_nodes)
        self._truth = _copy_tables(tables)
        s = self._truth["slot_lo"].shape[0]
        self._committed = np.zeros(s, np.uint32)
        self._staged = np.zeros(s, np.uint32)  # last committed vector staged
        # lease state machine
        self.leader_pos = 0
        self.lease_expires = cfg.lease_epochs
        self.lease_blocked = False  # active lease_expiry fault on the leader
        self.renewals = 0
        self.failovers = 0
        self.stall_pulls = 0
        # fault bookkeeping
        self.lag_mult = np.ones(self.n_switches, np.int64)
        self.rogue: set[int] = set()

    # -- derived ----------------------------------------------------------
    @property
    def leader(self) -> int:
        return self.chain[self.leader_pos]

    def bound(self) -> int:
        """Configured staleness bound: every switch converges to the
        committed table within this many epochs of the staging pull
        (absent an active lease stall or split-brain, which by design
        widen the window until resolved)."""
        if self.cfg.staleness_bound is not None:
            return self.cfg.staleness_bound
        return (self.n_switches - 1) * self.cfg.lag_per_hop * int(self.lag_mult.max())

    def _delays(self) -> np.ndarray:
        """Per-switch install delay: chain position relative to the
        current leader times the per-hop lag (scaled for drifted
        replicas)."""
        pos = (np.arange(self.n_switches) - self.leader_pos) % self.n_switches
        return pos * self.cfg.lag_per_hop * self.lag_mult

    # -- state construction ----------------------------------------------
    def make_state(self) -> CoordState:
        return ST.make_state(self._truth, self.n_switches)

    def rebuild(self, tables: dict) -> CoordState:
        """Full resync after a slot-pool growth: shapes changed, so every
        switch re-registers at the new width (pool growth is already a
        recompile barrier for the whole pipeline)."""
        self._truth = _copy_tables(tables)
        s = self._truth["slot_lo"].shape[0]
        self._committed = np.zeros(s, np.uint32)
        self._staged = np.zeros(s, np.uint32)
        self.rogue.clear()
        return self.make_state()

    # -- the control-write path -------------------------------------------
    def on_control(self, coord: CoordState, tables: dict, now: int) -> tuple[CoordState, list[str]]:
        """Runs at every control sync point (period pulls and event
        splices).  Diffs the table snapshot against the last one, bumps
        committed versions for rewritten slots, and — lease permitting —
        stages the new table along the chain."""
        notes: list[str] = []
        now = int(now)

        # lease: holding the control channel renews it; an active expiry
        # fault blocks renewal until failover or an explicit renew event.
        if not self.lease_blocked:
            self.lease_expires = now + self.cfg.lease_epochs
            self.renewals += 1
        elif now >= self.lease_expires + self.cfg.failover_after:
            self.leader_pos = (self.leader_pos + 1) % self.n_switches
            self.lease_blocked = False
            self.lease_expires = now + self.cfg.lease_epochs
            self.failovers += 1
            notes.append(f"coord_failover:sw{self.leader}")

        # diff: which slots did the controller rewrite since last sync?
        new = _copy_tables(tables)
        old = self._truth
        changed = (
            (new["slot_lo"] != old["slot_lo"])
            | (new["slot_hi"] != old["slot_hi"])
            | (new["live"] != old["live"])
            | (new["chains"] != old["chains"]).any(axis=1)
            | (new["chain_len"] != old["chain_len"])
        )
        self._truth = new
        n_changed = int(changed.sum())
        if n_changed:
            # the reconfiguration itself IS the quorum commit — serving
            # nodes learn their new ownership through the data plane, so
            # divergence detection fires even while switch staging stalls
            self._committed[changed] += 1
            coord = dataclasses.replace(coord, committed=jnp.asarray(self._committed))

        if self.lease_blocked:
            if (self._staged != self._committed).any():
                self.stall_pulls += 1
                notes.append(f"coord_stall:{int((self._staged != self._committed).sum())}")
            return coord, notes

        if (self._staged != self._committed).any():
            coord = self._stage(coord, now)
            notes.append(f"coord_stage:{n_changed}")
        return coord, notes

    def _stage(self, coord: CoordState, now: int) -> CoordState:
        t = self._truth
        install = np.full(self.n_switches, INSTALL_NEVER, np.int64)
        okay = np.ones(self.n_switches, bool)
        for w in self.rogue:  # a rogue switch ignores quorum installs
            okay[w] = False
        delays = self._delays()
        install[okay] = now + delays[okay]
        install = np.minimum(install, int(INSTALL_NEVER)).astype(np.int32)
        self._staged = self._committed.copy()
        return dataclasses.replace(
            coord,
            pend_lo=jnp.asarray(t["slot_lo"].astype(np.uint32)),
            pend_hi=jnp.asarray(t["slot_hi"].astype(np.uint32)),
            pend_live=jnp.asarray(t["live"].astype(bool)),
            pend_chains=jnp.asarray(t["chains"].astype(np.int32)),
            pend_clen=jnp.asarray(t["chain_len"].astype(np.int32)),
            pend_version=jnp.asarray(self._committed),
            install_at=jnp.asarray(install),
        )

    # -- fault injectors ---------------------------------------------------
    def on_event(self, kind: str, payload, coord: CoordState, tables: dict, now: int) -> tuple[CoordState, list[str]]:
        notes: list[str] = []
        if kind == "lease_expire":
            self.lease_blocked = True
            self.lease_expires = min(self.lease_expires, int(now))
            notes.append(f"coord_lease_expired:sw{self.leader}")
        elif kind == "lease_renew":
            self.lease_blocked = False
            self.lease_expires = int(now) + self.cfg.lease_epochs
            self.renewals += 1
            notes.append("coord_lease_renewed")
        elif kind == "split_brain":
            w = int(payload) % self.n_switches
            if w == self.leader_pos:
                w = (w + 1) % self.n_switches
            self.rogue.add(w)
            # the rogue claims leadership and installs its own divergent
            # table: same partition bounds, chain ownership rotated by one
            # node, versions self-stamped far past the quorum commit
            ch = self._truth["chains"]
            rogue_ch = np.where(ch >= 0, (ch + 1) % self.num_nodes, ch).astype(np.int32)
            rogue_v = (self._committed + np.uint32(1000)).astype(np.uint32)
            coord = dataclasses.replace(
                coord,
                chains=coord.chains.at[w].set(jnp.asarray(rogue_ch)),
                version=coord.version.at[w].set(jnp.asarray(rogue_v)),
                install_at=coord.install_at.at[w].set(jnp.int32(INSTALL_NEVER)),
            )
            notes.append(f"coord_split_brain:sw{w}")
        elif kind == "heal_split":
            t = self._truth
            for w in sorted(self.rogue):
                coord = dataclasses.replace(
                    coord,
                    slot_lo=coord.slot_lo.at[w].set(jnp.asarray(t["slot_lo"].astype(np.uint32))),
                    slot_hi=coord.slot_hi.at[w].set(jnp.asarray(t["slot_hi"].astype(np.uint32))),
                    live=coord.live.at[w].set(jnp.asarray(t["live"].astype(bool))),
                    chains=coord.chains.at[w].set(jnp.asarray(t["chains"].astype(np.int32))),
                    chain_len=coord.chain_len.at[w].set(jnp.asarray(t["chain_len"].astype(np.int32))),
                    version=coord.version.at[w].set(jnp.asarray(self._committed)),
                )
                notes.append(f"coord_heal:sw{w}")
            self.rogue.clear()
        elif kind == "quorum_drift":
            w = int(payload) % self.n_switches
            self.lag_mult[w] = self.cfg.drift_mult
            notes.append(f"coord_drift:sw{w}x{self.cfg.drift_mult}")
        else:
            raise ValueError(f"unknown coordination event kind: {kind!r}")
        return coord, notes

    # -- inspection --------------------------------------------------------
    def converged(self, coord: CoordState) -> bool:
        """Every switch's every slot at the committed version (one sync)."""
        v = np.asarray(coord.version)
        c = np.asarray(coord.committed)
        return bool((v == c[None, :]).all())

    def summary(self) -> dict:
        return {
            "n_switches": self.n_switches,
            "leader": self.leader,
            "renewals": self.renewals,
            "failovers": self.failovers,
            "stall_pulls": self.stall_pulls,
            "lease_blocked": self.lease_blocked,
            "rogue": sorted(self.rogue),
            "lag_mult": self.lag_mult.tolist(),
            "staleness_bound": self.bound(),
        }

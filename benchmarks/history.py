"""Benchmark history ledger: headline metrics per gated run, append-only.

Every gated benchmark run appends one JSONL record to ``BENCH_HISTORY.jsonl``
— git SHA, a short hash of the run configuration, and the headline numbers
an operator tracks across PRs (steady epochs/s proxy via mean throughput,
worst p99/p999, loss, coord redirect share).  The ledger is committed and
re-uploaded by CI, so perf trajectories survive artifact expiry.

Usage (wired into the bench ``main``s; also standalone):

  PYTHONPATH=src python -m benchmarks.history --append BENCH_dist.json
  PYTHONPATH=src python -m benchmarks.history --seed      # one entry per
                                                          # committed BENCH_*
  PYTHONPATH=src python -m benchmarks.history --show

Append never raises into the caller: a missing git binary or malformed doc
degrades to ``sha="unknown"`` / skipped fields, because losing a history
line must not fail a benchmark gate.
"""

from __future__ import annotations

import argparse
import datetime
import glob
import hashlib
import json
import os
import subprocess

HISTORY = "BENCH_HISTORY.jsonl"


def git_sha(cwd: str = ".") -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short=12", "HEAD"], cwd=cwd,
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def config_hash(doc: dict) -> str:
    """Short stable hash of the run configuration (non-row keys)."""
    cfg = {k: v for k, v in doc.items() if k != "rows"}
    blob = json.dumps(cfg, sort_keys=True, default=str).encode()
    return hashlib.sha256(blob).hexdigest()[:12]


def _agg(rows: list[dict], key: str, fn=max):
    vals = [r[key] for r in rows if isinstance(r.get(key), (int, float))]
    return fn(vals) if vals else None


def headline(bench: str, doc: dict) -> dict:
    """Distill a bench JSON into the fixed headline record."""
    rows = doc.get("rows", [])
    rec = {
        "bench": bench,
        "n_rows": len(rows),
        "steady_eps": _agg(rows, "mean_throughput"),
        "p99": _agg(rows, "max_p99"),
        "p999": _agg(rows, "max_p999"),
        "loss": _agg(rows, "lost", fn=sum),
        "redirect_share": _agg(rows, "redirect_share"),
    }
    # metrics-plane smoke docs carry their gates at the top level
    for k in ("parity_ok", "alert_epoch_ok", "incident_complete"):
        if k in doc:
            rec[k] = doc[k]
    return rec


def append(bench: str, doc: dict, *, history_path: str = HISTORY,
           cwd: str = ".") -> dict | None:
    """Append one headline record; returns it (None on failure)."""
    try:
        rec = headline(bench, doc)
        rec["sha"] = git_sha(cwd)
        rec["config_hash"] = config_hash(doc)
        rec["ts"] = datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds")
        with open(history_path, "a") as f:
            f.write(json.dumps(rec, default=str) + "\n")
        return rec
    except Exception:
        return None


def append_file(path: str, *, bench: str | None = None,
                history_path: str = HISTORY) -> dict | None:
    with open(path) as f:
        doc = json.load(f)
    if bench is None:
        bench = os.path.basename(path)
        bench = bench[len("BENCH_"):] if bench.startswith("BENCH_") else bench
        bench = bench.rsplit(".", 1)[0]
    return append(bench, doc, history_path=history_path,
                  cwd=os.path.dirname(os.path.abspath(path)))


def load(history_path: str = HISTORY) -> list[dict]:
    if not os.path.exists(history_path):
        return []
    out = []
    with open(history_path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def fmt(records: list[dict]) -> str:
    hdr = ("| ts | sha | bench | cfg | steady eps | p99 | p999 | loss "
           "| redirect |\n|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in records:
        def g(k, spec="{:.3g}"):
            v = r.get(k)
            return spec.format(v) if isinstance(v, (int, float)) else "-"
        lines.append(
            f"| {r.get('ts', '-')} | {r.get('sha', '-')} "
            f"| {r.get('bench', '-')} | {r.get('config_hash', '-')} "
            f"| {g('steady_eps')} | {g('p99')} | {g('p999')} "
            f"| {g('loss')} | {g('redirect_share')} |"
        )
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--append", metavar="BENCH_JSON", default=None,
                    help="append one headline record from this bench JSON")
    ap.add_argument("--bench", default=None,
                    help="bench name override for --append")
    ap.add_argument("--seed", action="store_true",
                    help="append one record per committed BENCH_*.json")
    ap.add_argument("--show", action="store_true",
                    help="print the ledger as a markdown table")
    ap.add_argument("--history", default=HISTORY)
    args = ap.parse_args(argv)
    if args.append:
        rec = append_file(args.append, bench=args.bench,
                          history_path=args.history)
        print(json.dumps(rec) if rec else "append failed")
    if args.seed:
        for path in sorted(glob.glob("BENCH_*.json")):
            if "roofline" in path or "HISTORY" in path:
                continue
            rec = append_file(path, history_path=args.history)
            print(f"{path}: {'ok' if rec else 'skipped'}")
    if args.show or not (args.append or args.seed):
        print(fmt(load(args.history)))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Overload-survival benchmark: the closed loop vs. the retry storm.

Runs the two overload stressors (``cascade_failure``: a rack dies for
good and the survivors inherit its traffic; ``retry_storm``: a transient
outage whose shed queries re-fire together on recovery) with the
device-resident overload plane (``repro.overload``) **enabled in both
arms** — identical queue physics, identical standby reserve — and only
the control plane differing:

* ``plain``      — ``full_adaptive``: the pre-PR-6 loop.  Migrates and
  replicates, but admission stays open, retry re-entry is unbounded, and
  the standby reserve is never recruited;
* ``controlled`` — ``overload_adaptive``: AIMD admission probabilities,
  retry budgets at a fraction of the service rate, and capacity
  autoscale closing the loop on the reserve.

**Survival gate** (CI-enforced, per scenario):

* controlled arm: ``cum_lost == 0`` (no query ever escapes the top
  backoff level), final retry backlog under ``BACKLOG_FRAC`` of injected
  (the storm *drains* instead of standing), and ``max p999 <=
  P999_BOUND`` (the tail stays bounded through the failure);
* plain arm: violates at least one of the three on the same scenario —
  the uncontrolled loop demonstrably collapses where the controlled one
  survives;
* every run: ``conservation_gap == 0`` (no query silently leaks) and
  one compiled step per scenario.

``--trace`` additionally runs the retry storm's controlled arm with
``repro.telemetry`` span sampling on, asserting off-mode bit-parity /
one compiled step / exact span reconstruction, and emits two artifacts:
a Chrome trace (``TRACE_overload.json``) and the **p999 attribution
breakdown** (``ATTRIB_retry_storm.json``) — where the extreme tail's
latency mass actually sits ({queue, inflation, bounce, retry_backoff,
service}) during the storm.

Run: ``PYTHONPATH=src python -m benchmarks.overload_bench
[--quick] [--scenarios a,b] [--trace] [--json BENCH_overload.json]
[--no-check]``
"""

from __future__ import annotations

import argparse
import json
import sys
import time

SCENARIOS = ("cascade_failure", "retry_storm")
ARMS = (("plain", "full_adaptive"), ("controlled", "overload_adaptive"))

# survival-gate bounds.  p999 is in DES ticks and scales with epoch size,
# so each matrix size carries its own bound (set ~25% above the measured
# controlled-arm tail so a real regression trips it, quick CI noise does
# not); the backlog fraction is size-invariant.
P999_BOUND = {True: 350.0, False: 400.0}
BACKLOG_FRAC = 0.02


def overload_config(quick: bool):
    from repro.overload import OverloadConfig

    # queue_cap ~ 60% of a survivor's post-failure epoch share, service
    # just above the pre-failure share: comfortable until the rack dies,
    # unstable after — the regime the controller must manage
    if quick:
        return OverloadConfig(queue_cap=48, service_rate=80, inflation=3.0,
                              max_level=3, backoff_base=1, jitter_span=2,
                              queue_weight=2)
    return OverloadConfig(queue_cap=192, service_rate=320, inflation=3.0,
                          max_level=3, backoff_base=1, jitter_span=2,
                          queue_weight=2)


def scenario_config(quick: bool):
    from repro.cluster import ScenarioConfig

    if quick:
        return ScenarioConfig(n_epochs=16, epoch_ops=512, n_records=2048,
                              value_dim=4, seed=7)
    return ScenarioConfig(n_epochs=24, epoch_ops=2048, n_records=4096,
                          value_dim=8, seed=7)


def cluster_config(quick: bool):
    from repro.cluster import ClusterConfig

    return ClusterConfig(num_nodes=10, num_ranges=20, replication=2,
                         overload=overload_config(quick),
                         standby_nodes=(8, 9), report_every=2)


def policy_for(arm: str):
    from repro.cluster import make_policy
    from repro.cluster.policies import PolicyConfig

    if arm == "controlled":
        return make_policy("overload_adaptive",
                           PolicyConfig(scale_patience=1))
    return make_policy("full_adaptive")


def run_matrix(scenarios, quick: bool, verbose: bool = True):
    from repro.cluster import EpochDriver, make_scenario, summarize
    from repro.overload import conservation_gap

    rows = []
    for sname in scenarios:
        for arm, pname in ARMS:
            scen = make_scenario(sname, scenario_config(quick))
            drv = EpochDriver(scen, policy_for(arm), cluster_config(quick))
            t0 = time.perf_counter()
            epochs = drv.run()
            wall = time.perf_counter() - t0
            row = summarize(epochs)
            row.update(drv.overload_summary())
            row["arm"] = arm
            row["wall_s"] = round(wall, 3)
            row["traces"] = drv.traces
            row["conservation_gap"] = conservation_gap(drv.ovl)
            row["autoscale_events"] = [
                e for r in epochs for e in r.events
                if e.startswith("autoscale_")
            ]
            rows.append(row)
            if verbose:
                print(
                    f"{sname:16s} {arm:10s} lost {row['lost']:5d} "
                    f"backlog {row['retry_backlog']:5d} "
                    f"shed {row['total_shed']:5d} "
                    f"deferred {row['total_deferred']:5d} "
                    f"max_p999 {row['max_p999']:7.1f} "
                    f"traces {row['traces']}"
                )
    return rows


TRACE_SCENARIO = "retry_storm"
TRACE_ARM = "controlled"
TRACE_ARTIFACT = "TRACE_overload.json"
ATTRIB_ARTIFACT = "ATTRIB_retry_storm.json"
ATTRIB_Q = 99.9


def run_trace(quick: bool, out: str = TRACE_ARTIFACT,
              attrib_out: str = ATTRIB_ARTIFACT
              ) -> tuple[list[dict], list[str]]:
    """The telemetry column: retry storm, controlled arm, sampling on.

    Asserts the PR-7 telemetry contracts (off-mode ``EpochMetrics``
    bit-parity, one compiled step with tracing enabled, exact span
    latency reconstruction) and emits the Chrome trace plus the p999
    attribution breakdown — the storm's extreme tail bucketed into
    {queue, inflation, bounce, retry_backoff, service} mass.
    """
    import dataclasses

    from repro.cluster import EpochDriver, TelemetryConfig, make_scenario

    scfg = scenario_config(quick)
    tcfg = TelemetryConfig(sample_rate=1 / 4 if quick else 1 / 64)

    def drive(tel):
        scen = make_scenario(TRACE_SCENARIO, scfg)
        drv = EpochDriver(scen, policy_for(TRACE_ARM),
                          dataclasses.replace(cluster_config(quick),
                                              telemetry=tel))
        return drv, drv.run()

    _, base = drive(None)
    drv, traced = drive(tcfg)

    problems = []
    if [r.to_row() for r in base] != [r.to_row() for r in traced]:
        problems.append(
            "trace: telemetry-on EpochMetrics rows differ from the "
            "telemetry-off run (tracing perturbed the metric stream)")
    if drv.traces != 1:
        problems.append(
            f"trace: epoch step traced {drv.traces}x with sampling on "
            "(expected 1)")
    err = drv.telemetry.verify_exact()
    if err != 0.0:
        problems.append(
            f"trace: span latency reconstruction off by {err!r} "
            "(must be exactly 0.0)")
    if drv.telemetry.span_count == 0:
        problems.append("trace: sampling enabled but zero spans recorded")

    path = drv.telemetry.write_chrome_trace(out)
    attrib = drv.telemetry.attribution(ATTRIB_Q)
    with open(attrib_out, "w") as f:
        json.dump({"scenario": TRACE_SCENARIO, "arm": TRACE_ARM,
                   "quick": quick, "sample_rate": tcfg.sample_rate,
                   "spans": drv.telemetry.span_count,
                   "attribution": attrib}, f, indent=1)

    row = {
        "trace": True,
        "scenario": TRACE_SCENARIO,
        "arm": TRACE_ARM,
        "sample_rate": tcfg.sample_rate,
        "spans": drv.telemetry.span_count,
        "reconstruction_max_err": err,
        "traces": drv.traces,
        "parity": not problems,
        "attribution": attrib,
        "artifacts": [path, attrib_out],
    }
    share = attrib.get("share", {})
    top = max(share, key=share.get) if share else "n/a"
    print(
        f"[trace] {TRACE_SCENARIO}/{TRACE_ARM} spans {row['spans']} "
        f"reconstruction err {err!r} traces {drv.traces}; p{ATTRIB_Q} "
        f"tail mass mostly '{top}' "
        f"({share.get(top, 0.0):.0%}) -> {path}, {attrib_out}"
    )
    return [row], problems


def check_survival(rows, *, quick: bool) -> list[str]:
    """The survival gate: controlled survives, plain collapses."""
    bound = P999_BOUND[quick]
    rows = [r for r in rows if not r.get("trace")]
    by = {(r["scenario"], r["arm"]): r for r in rows}
    problems = []

    def violations(r):
        v = []
        if r["lost"] > 0:
            v.append(f"lost {r['lost']} queries")
        if r["retry_backlog"] > BACKLOG_FRAC * r["injected"]:
            v.append(f"standing backlog {r['retry_backlog']}")
        if r["max_p999"] > bound:
            v.append(f"p999 {r['max_p999']:.1f} > {bound}")
        return v

    for r in rows:
        if r["conservation_gap"] != 0:
            problems.append(
                f"{r['scenario']}/{r['arm']}: conservation gap "
                f"{r['conservation_gap']} (queries leaked)")
        if r["traces"] != 1:
            problems.append(
                f"{r['scenario']}/{r['arm']}: {r['traces']} compiled "
                f"steps (expected 1)")

    for scen in {r["scenario"] for r in rows}:
        ctrl = by.get((scen, "controlled"))
        plain = by.get((scen, "plain"))
        if ctrl:
            v = violations(ctrl)
            if v:
                problems.append(f"{scen}/controlled did not survive: "
                                + "; ".join(v))
            if not ctrl["autoscale_events"]:
                problems.append(
                    f"{scen}/controlled never recruited the reserve")
        if plain and not violations(plain):
            problems.append(
                f"{scen}/plain survived — the stressor is not stressing "
                f"(lost 0, backlog {plain['retry_backlog']}, "
                f"p999 {plain['max_p999']:.1f})")
    return problems


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke sizes (16 epochs x 512 ops)")
    ap.add_argument("--scenarios", default=",".join(SCENARIOS))
    ap.add_argument("--trace", action="store_true",
                    help="also run the telemetry column on the retry "
                         "storm and emit Chrome-trace + p999 attribution "
                         "artifacts")
    ap.add_argument("--json", default=None, help="write rows to this path")
    ap.add_argument("--no-check", action="store_true",
                    help="skip the survival gate (exploratory runs)")
    args = ap.parse_args(argv)

    scenarios = [s for s in args.scenarios.split(",") if s]
    rows = run_matrix(scenarios, args.quick)

    trace_problems: list[str] = []
    if args.trace:
        trace_rows, trace_problems = run_trace(args.quick)
        rows.extend(trace_rows)

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"quick": args.quick, "rows": rows}, f, indent=1)
        print(f"wrote {args.json} ({len(rows)} rows)")
        from benchmarks import history
        history.append("overload", {"quick": args.quick, "rows": rows})

    if not args.no_check:
        problems = check_survival(rows, quick=args.quick) + trace_problems
        if problems:
            print("SURVIVAL GATE FAILED:")
            for p in problems:
                print("  -", p)
            return 1
        print("survival gate: controlled arm lost 0 queries, drained its "
              "backlog and kept p999 bounded on every scenario; the "
              "uncontrolled arm collapsed on every scenario; accounting "
              "conserved; one compiled step per run"
              + ("; telemetry parity + exact reconstruction held"
                 if args.trace else ""))
    return 0


if __name__ == "__main__":
    sys.exit(main())

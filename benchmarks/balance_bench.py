"""Adaptive-balancing benchmark: the policy zoo over the scenario library.

Runs the ``repro.cluster`` closed loop over the time-varying scenario
library and emits one JSON row per (scenario × policy) run — the numbers
behind BENCHMARKS.md §"Load balancing" and §"Hot-range splitting".  Two
acceptance gates are checked explicitly:

* **adaptive gate** (PR 2): on the Zipf-1.2 shifting hotspot,
  ``full_adaptive`` must beat the frozen directory on both mean load
  imbalance (max/mean) and mean DES p99 latency;
* **splitting gate** (PR 3): on the Zipf-1.3 multi-hotspot workload,
  ``split_hot`` must beat whole-range ``migrate`` on mean load imbalance
  at **equal or fewer** migrated entries (hot-subset moves are priced by
  the hot keys only), and every run's epoch step must compile exactly
  once.

Extras:

* ``--service lognormal|pareto`` re-runs the matrix under seeded per-hop
  service-time draws (``core.ServiceModel``) — the deterministic-service
  rows hide self-similar burstiness;
* ``--dist`` runs the dist-backend parity column (``make_dist_apply`` on
  a forced 8-device host mesh, in a subprocess because jax pins the
  device count at first init) and reports bucket-overflow retry rates
  under switch queue pressure.

Run: ``PYTHONPATH=src python -m benchmarks.balance_bench
[--quick] [--scenarios a,b] [--policies x,y] [--service kind] [--dist]
[--json BENCH_balance.json]``
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

DEFAULT_POLICIES = ("frozen", "migrate", "replicate", "split_hot", "full_adaptive")
DEFAULT_SCENARIOS = (
    "shifting_hotspot", "flash_crowd", "diurnal", "node_failure",
    "multi_hotspot", "keyspace_growth",
)
DIST_SCENARIO = "flash_crowd"                 # switch queue pressure case
DIST_POLICIES = ("frozen", "full_adaptive")


# the acceptance-gate cluster geometry: fine ranges so a Zipf hot block
# spans several chains, headroom for selective replication and splitting
def cluster_config(quick: bool, service: str = "fixed"):
    from repro.cluster import ClusterConfig
    from repro.core import ServiceModel

    return ClusterConfig(
        num_nodes=8,
        num_ranges=32 if quick else 128,
        replication=2,
        r_max=4 if quick else 5,
        n_clients=32,
        imbalance_threshold=1.1,
        max_moves_per_round=8,
        service_model=ServiceModel(kind=service),
    )


def scenario_config(quick: bool):
    from repro.cluster import ScenarioConfig

    if quick:
        return ScenarioConfig(n_epochs=4, epoch_ops=512, n_records=1024,
                              value_dim=4, seed=1, read_ratio=0.95)
    return ScenarioConfig(n_epochs=10, epoch_ops=1024, n_records=2048,
                          value_dim=4, seed=1, read_ratio=0.95)


def scenario_kwargs(name: str, scfg) -> dict:
    mid = scfg.n_epochs // 2
    return {
        "shifting_hotspot": dict(theta=1.2, shift_every=max(scfg.n_epochs // 3, 1)),
        "flash_crowd": dict(t0=mid // 2, t1=mid + 1),
        "diurnal": {},
        "node_failure": dict(fail_epoch=mid, fail_node=0),
        "multi_hotspot": dict(theta=1.3, n_hotspots=3,
                              shift_every=max(scfg.n_epochs // 3, 1)),
        "keyspace_growth": {},
        "stationary": {},
    }[name]


def run_matrix(scenarios, policies, quick: bool, *, service: str = "fixed",
               backend: str = "oracle", mesh=None, dist_cfg=None,
               verbose: bool = True):
    from repro.cluster import EpochDriver, make_policy, make_scenario, summarize

    rows = []
    for sname in scenarios:
        scfg = scenario_config(quick)
        for pname in policies:
            scen = make_scenario(sname, scfg, **scenario_kwargs(sname, scfg))
            drv = EpochDriver(scen, make_policy(pname),
                              cluster_config(quick, service),
                              backend=backend, mesh=mesh, dist_cfg=dist_cfg)
            t0 = time.perf_counter()
            epochs = drv.run()
            wall = time.perf_counter() - t0
            row = summarize(epochs)
            row["wall_s"] = round(wall, 3)
            row["traces"] = drv.traces
            row["service"] = service
            row["backend"] = backend
            rows.append(row)
            if verbose:
                print(
                    f"{sname:18s} {pname:14s} imb {row['mean_imbalance']:5.2f} "
                    f"p99 {row['mean_p99']:6.1f} p50 {row['mean_p50']:6.1f} "
                    f"thr {row['mean_throughput']:.3f} "
                    f"ent {row['total_migration_entries']:6d} "
                    f"retries {row['total_retries']:4d} "
                    f"traces {row['traces']}"
                )
    return rows


def check_acceptance(rows, *, quick: bool = False) -> list[str]:
    """The cluster-subsystem acceptance gates (see ISSUE/BENCHMARKS.md).

    ``quick`` (CI smoke sizes: 4 epochs) relaxes the splitting gate's
    imbalance comparison to "no worse" — at smoke scale a couple of
    control rounds cannot reliably separate the policies' imbalance
    means, but the keys-moved advantage and the compile-once property
    must hold at any size.
    """
    by = {(r["scenario"], r["policy"]): r for r in rows
          if r.get("backend", "oracle") == "oracle"}
    problems = []
    f = by.get(("shifting_hotspot", "frozen"))
    a = by.get(("shifting_hotspot", "full_adaptive"))
    if f and a:
        if not a["mean_imbalance"] < f["mean_imbalance"]:
            problems.append(
                f"full_adaptive imbalance {a['mean_imbalance']:.2f} !< "
                f"frozen {f['mean_imbalance']:.2f}"
            )
        if not a["mean_p99"] < f["mean_p99"]:
            problems.append(
                f"full_adaptive p99 {a['mean_p99']:.1f} !< "
                f"frozen {f['mean_p99']:.1f}"
            )
    # splitting gate: hot-subset control beats whole-range migration on
    # imbalance without moving more data
    m = by.get(("multi_hotspot", "migrate"))
    s = by.get(("multi_hotspot", "split_hot"))
    if m and s:
        ok = (s["mean_imbalance"] <= m["mean_imbalance"] if quick
              else s["mean_imbalance"] < m["mean_imbalance"])
        if not ok:
            problems.append(
                f"split_hot imbalance {s['mean_imbalance']:.2f} !< "
                f"migrate {m['mean_imbalance']:.2f}"
            )
        if not s["total_migration_entries"] <= m["total_migration_entries"]:
            problems.append(
                f"split_hot moved {s['total_migration_entries']} entries "
                f"!<= migrate {m['total_migration_entries']}"
            )
    for r in rows:
        if r["traces"] != 1:
            problems.append(
                f"{r['scenario']}/{r['policy']}: epoch step traced "
                f"{r['traces']}x (expected 1)"
            )
    return problems


def run_dist_parity(quick: bool) -> list[dict]:
    """Dist-backend parity column in a subprocess (forced 8-device mesh).

    jax pins the host device count at first init, so the parent process
    (which already initialized jax for the oracle matrix) cannot host the
    mesh itself.
    """
    env = {
        **os.environ,
        "XLA_FLAGS": "--xla_force_host_platform_device_count=8 "
        + os.environ.get("XLA_FLAGS", ""),
        "JAX_PLATFORMS": "cpu",
    }
    cmd = [sys.executable, "-m", "benchmarks.balance_bench", "--dist-worker"]
    if quick:
        cmd.append("--quick")
    r = subprocess.run(cmd, env=env, capture_output=True, text=True)
    if r.returncode != 0:
        print(r.stdout)
        print(r.stderr, file=sys.stderr)
        raise RuntimeError("dist parity worker failed")
    payload = json.loads(r.stdout.splitlines()[-1])
    return payload["rows"]


def dist_worker(quick: bool) -> int:
    import jax
    from repro.core import DistConfig

    mesh = jax.make_mesh((8,), ("data",))
    # a tight per-(source,target) queue bound so the flash crowd actually
    # exercises switch queue pressure: overflowing queries are dropped
    # and counted as client retries (the quantity this column reports)
    dist_cfg = DistConfig(bucket_cap=16 if quick else 24)
    rows = run_matrix([DIST_SCENARIO], list(DIST_POLICIES), quick,
                      backend="dist", mesh=mesh, dist_cfg=dist_cfg,
                      verbose=False)
    print(json.dumps({"rows": rows}))
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="tiny sizes (CI smoke)")
    ap.add_argument("--scenarios", default=",".join(DEFAULT_SCENARIOS))
    ap.add_argument("--policies", default=",".join(DEFAULT_POLICIES))
    ap.add_argument("--service", default="fixed",
                    choices=("fixed", "lognormal", "pareto"),
                    help="per-hop service-time distribution (ServiceModel)")
    ap.add_argument("--dist", action="store_true",
                    help="also run the dist-backend parity column "
                         "(8-device host mesh subprocess)")
    ap.add_argument("--dist-worker", action="store_true",
                    help=argparse.SUPPRESS)  # internal: the forked mesh run
    ap.add_argument("--json", default=None, help="write rows to this path")
    ap.add_argument("--no-check", action="store_true",
                    help="skip the acceptance gate (exploratory runs)")
    args = ap.parse_args(argv)

    if args.dist_worker:
        return dist_worker(args.quick)

    scenarios = [s for s in args.scenarios.split(",") if s]
    policies = [p for p in args.policies.split(",") if p]
    rows = run_matrix(scenarios, policies, args.quick, service=args.service)

    if args.dist:
        dist_rows = run_dist_parity(args.quick)
        for r in dist_rows:
            print(
                f"[dist] {r['scenario']:14s} {r['policy']:14s} "
                f"imb {r['mean_imbalance']:5.2f} p99 {r['mean_p99']:6.1f} "
                f"retries {r['total_retries']:4d} "
                f"({r['total_retries'] / max(r['epochs'], 1):.1f}/epoch)"
            )
        rows.extend(dist_rows)

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"quick": args.quick, "service": args.service,
                       "rows": rows}, f, indent=1)
        print(f"wrote {args.json} ({len(rows)} rows)")

    if not args.no_check:
        problems = check_acceptance(rows, quick=args.quick)
        if problems:
            print("ACCEPTANCE FAILED:")
            for p in problems:
                print("  -", p)
            return 1
        gates = []
        if "shifting_hotspot" in scenarios:
            gates.append("full_adaptive < frozen on imbalance AND p99")
        if "multi_hotspot" in scenarios:
            gates.append("split_hot < migrate on imbalance at <= entries moved")
        gates.append("all steps compiled once")
        print("acceptance: " + "; ".join(gates))
    return 0


if __name__ == "__main__":
    sys.exit(main())

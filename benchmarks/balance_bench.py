"""Adaptive-balancing benchmark: frozen vs migrate-only vs full-adaptive.

Runs the ``repro.cluster`` closed loop over the time-varying scenario
library and emits one JSON row per (scenario × policy) run — the numbers
behind BENCHMARKS.md §"Load balancing".  The acceptance gate of the
cluster subsystem is checked here explicitly: on the Zipf-1.2
shifting-hotspot scenario the full-adaptive policy must beat the
frozen-directory baseline on **both** mean load imbalance (max/mean) and
mean DES p99 latency, with the epoch device step compiled exactly once
per scenario.

Run: ``PYTHONPATH=src python -m benchmarks.balance_bench
[--quick] [--scenarios a,b] [--policies x,y] [--json BENCH_balance.json]``
"""

from __future__ import annotations

import argparse
import json
import sys
import time

from repro.cluster import (
    ClusterConfig,
    EpochDriver,
    ScenarioConfig,
    make_policy,
    make_scenario,
    summarize,
)

DEFAULT_POLICIES = ("frozen", "migrate", "replicate", "full_adaptive")
DEFAULT_SCENARIOS = ("shifting_hotspot", "flash_crowd", "diurnal", "node_failure")

# the acceptance-gate cluster geometry: fine ranges so a Zipf-1.2 hot
# block spans several chains, headroom for selective replication
def cluster_config(quick: bool) -> ClusterConfig:
    return ClusterConfig(
        num_nodes=8,
        num_ranges=32 if quick else 128,
        replication=2,
        r_max=4 if quick else 5,
        n_clients=32,
        imbalance_threshold=1.1,
        max_moves_per_round=8,
    )


def scenario_config(quick: bool) -> ScenarioConfig:
    if quick:
        return ScenarioConfig(n_epochs=4, epoch_ops=512, n_records=1024,
                              value_dim=4, seed=1, read_ratio=0.95)
    return ScenarioConfig(n_epochs=10, epoch_ops=1024, n_records=2048,
                          value_dim=4, seed=1, read_ratio=0.95)


def scenario_kwargs(name: str, scfg: ScenarioConfig) -> dict:
    mid = scfg.n_epochs // 2
    return {
        "shifting_hotspot": dict(theta=1.2, shift_every=max(scfg.n_epochs // 3, 1)),
        "flash_crowd": dict(t0=mid // 2, t1=mid + 1),
        "diurnal": {},
        "node_failure": dict(fail_epoch=mid, fail_node=0),
        "stationary": {},
    }[name]


def run_matrix(scenarios, policies, quick: bool, verbose: bool = True):
    rows = []
    for sname in scenarios:
        scfg = scenario_config(quick)
        for pname in policies:
            scen = make_scenario(sname, scfg, **scenario_kwargs(sname, scfg))
            drv = EpochDriver(scen, make_policy(pname), cluster_config(quick))
            t0 = time.perf_counter()
            epochs = drv.run()
            wall = time.perf_counter() - t0
            row = summarize(epochs)
            row["wall_s"] = round(wall, 3)
            row["traces"] = drv.traces
            rows.append(row)
            if verbose:
                print(
                    f"{sname:18s} {pname:14s} imb {row['mean_imbalance']:5.2f} "
                    f"p99 {row['mean_p99']:6.1f} p50 {row['mean_p50']:6.1f} "
                    f"thr {row['mean_throughput']:.3f} "
                    f"migB {row['total_migration_bytes']:8d} "
                    f"traces {row['traces']}"
                )
    return rows


def check_acceptance(rows) -> list[str]:
    """The cluster-subsystem acceptance gate (see ISSUE/BENCHMARKS.md)."""
    by = {(r["scenario"], r["policy"]): r for r in rows}
    problems = []
    f = by.get(("shifting_hotspot", "frozen"))
    a = by.get(("shifting_hotspot", "full_adaptive"))
    if f and a:
        if not a["mean_imbalance"] < f["mean_imbalance"]:
            problems.append(
                f"full_adaptive imbalance {a['mean_imbalance']:.2f} !< "
                f"frozen {f['mean_imbalance']:.2f}"
            )
        if not a["mean_p99"] < f["mean_p99"]:
            problems.append(
                f"full_adaptive p99 {a['mean_p99']:.1f} !< "
                f"frozen {f['mean_p99']:.1f}"
            )
    for r in rows:
        if r["traces"] != 1:
            problems.append(
                f"{r['scenario']}/{r['policy']}: epoch step traced "
                f"{r['traces']}x (expected 1)"
            )
    return problems


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="tiny sizes (CI smoke)")
    ap.add_argument("--scenarios", default=",".join(DEFAULT_SCENARIOS))
    ap.add_argument("--policies", default=",".join(DEFAULT_POLICIES))
    ap.add_argument("--json", default=None, help="write rows to this path")
    ap.add_argument("--no-check", action="store_true",
                    help="skip the acceptance gate (exploratory runs)")
    args = ap.parse_args(argv)

    scenarios = [s for s in args.scenarios.split(",") if s]
    policies = [p for p in args.policies.split(",") if p]
    rows = run_matrix(scenarios, policies, args.quick)

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"quick": args.quick, "rows": rows}, f, indent=1)
        print(f"wrote {args.json} ({len(rows)} rows)")

    if not args.no_check and "shifting_hotspot" in scenarios:
        problems = check_acceptance(rows)
        if problems:
            print("ACCEPTANCE FAILED:")
            for p in problems:
                print("  -", p)
            return 1
        print("acceptance: full_adaptive < frozen on imbalance AND p99; "
              "all steps compiled once")
    return 0


if __name__ == "__main__":
    sys.exit(main())

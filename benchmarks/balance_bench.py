"""Adaptive-balancing benchmark: the policy zoo over the scenario library.

Runs the ``repro.cluster`` closed loop over the time-varying scenario
library and emits one JSON row per (scenario × policy) run — the numbers
behind BENCHMARKS.md §"Load balancing" and §"Hot-range splitting".  Two
acceptance gates are checked explicitly:

* **adaptive gate** (PR 2): on the Zipf-1.2 shifting hotspot,
  ``full_adaptive`` must beat the frozen directory on both mean load
  imbalance (max/mean) and mean DES p99 latency;
* **splitting gate** (PR 3): on the Zipf-1.3 multi-hotspot workload,
  ``split_hot`` must beat whole-range ``migrate`` on mean load imbalance
  at **equal or fewer** migrated entries (hot-subset moves are priced by
  the hot keys only), and every run's epoch step must compile exactly
  once.

Extras:

* ``--service lognormal|pareto`` re-runs the matrix under seeded per-hop
  service-time draws (``core.ServiceModel``) — the deterministic-service
  rows hide self-similar burstiness;
* ``--dist`` runs the dist-backend parity column (``make_dist_apply`` on
  a forced 8-device host mesh, in a subprocess because jax pins the
  device count at first init) and reports bucket-overflow retry rates
  under switch queue pressure;
* ``--period N`` sets the control-pull cadence (= the fused scan length;
  default 1 so the gate matrix's policy decisions stay comparable to the
  per-epoch PR-3 rows — raise it to trade control lag for throughput);
  ``--period auto`` turns on the drift-adaptive cadence
  (``Policy.pull_every="auto"``): each report picks the next period from
  report-to-report load drift inside ``ClusterConfig.auto_band``;
* ``--profile`` runs the epoch-pipeline comparison: fused vs per-epoch
  driver on the same scenario with the whole run fused into one period,
  reporting compile vs steady-state epochs/s and host-sync counts, and
  **gating** on the fused driver beating the per-epoch one (the CI smoke
  ratio + host-sync gates);
* ``--trace`` runs the telemetry parity column: the gate pair re-runs
  with ``repro.telemetry`` span sampling enabled, asserting the
  :class:`EpochMetrics` stream is **bit-identical** to the
  telemetry-off run, the step still compiles once, and every sampled
  span's latency decomposition reconstructs its DES latency exactly;
  emits a Chrome-trace artifact (``TRACE_balance.json`` by default)
  loadable in ``chrome://tracing`` / Perfetto;
* ``--replication`` runs the ``repro.replication`` three-mode comparison
  (eventual / chain / craq over diurnal, write-heavy flash-crowd and
  YCSB-A mixes) with its own gates: craq clean-read p99 must not exceed
  chain tail-read p99 on the read-heavy diurnal phase, only craq may
  (and must, under writes) report dirty-read bounces, and every step
  compiles once.  The gate matrix itself stays in ``eventual`` mode, so
  PR-2/3/4 comparisons are untouched.

Run: ``PYTHONPATH=src python -m benchmarks.balance_bench
[--quick] [--scenarios a,b] [--policies x,y] [--service kind] [--dist]
[--period N|auto] [--profile] [--trace] [--replication]
[--json BENCH_balance.json]``
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

DEFAULT_POLICIES = ("frozen", "migrate", "replicate", "split_hot", "full_adaptive")
DEFAULT_SCENARIOS = (
    "shifting_hotspot", "flash_crowd", "diurnal", "node_failure",
    "multi_hotspot", "keyspace_growth", "rack_failure_hotspot",
)
DIST_SCENARIO = "flash_crowd"                 # switch queue pressure case
DIST_POLICIES = ("frozen", "full_adaptive")
# gate-matrix pull cadence: 1 keeps every policy decision identical to the
# per-epoch PR-3 rows, so the adaptive/splitting gates compare unchanged
# behaviour; the pipeline win is measured by --profile (which fuses whole
# periods) and by exploratory --period runs
DEFAULT_PERIOD = 1
# the --profile comparison pair (the tentpole's acceptance scenario)
PROFILE_SCENARIO = "shifting_hotspot"
PROFILE_POLICIES = ("frozen", "full_adaptive")
# fused steady-state epochs/s vs the per-epoch driver, gated at two
# deliberately generous levels.  Full size measures >1.5x; quick sizes
# (4 epochs x 512 ops on a 2-core CI box) measure ~1.1-1.5x with
# run-to-run noise that straddles 1.0, so the quick gate only requires
# "not materially slower" — it still catches a real pipeline regression
# (a broken scan measures ~0.3x) without flaking CI.  host-sync counts
# gate deterministically alongside it.
PROFILE_RATIO_GATE = 1.2
PROFILE_RATIO_GATE_QUICK = 0.9


# the acceptance-gate cluster geometry: fine ranges so a Zipf hot block
# spans several chains, headroom for selective replication and splitting
def cluster_config(quick: bool, service: str = "fixed",
                   period=DEFAULT_PERIOD):
    from repro.cluster import ClusterConfig
    from repro.core import ServiceModel

    return ClusterConfig(
        num_nodes=8,
        num_ranges=32 if quick else 128,
        replication=2,
        r_max=4 if quick else 5,
        n_clients=32,
        report_every=period,
        imbalance_threshold=1.1,
        max_moves_per_round=8,
        service_model=ServiceModel(kind=service),
    )


def scenario_config(quick: bool):
    from repro.cluster import ScenarioConfig

    if quick:
        return ScenarioConfig(n_epochs=4, epoch_ops=512, n_records=1024,
                              value_dim=4, seed=1, read_ratio=0.95)
    return ScenarioConfig(n_epochs=10, epoch_ops=1024, n_records=2048,
                          value_dim=4, seed=1, read_ratio=0.95)


def scenario_kwargs(name: str, scfg) -> dict:
    mid = scfg.n_epochs // 2
    return {
        "shifting_hotspot": dict(theta=1.2, shift_every=max(scfg.n_epochs // 3, 1)),
        "flash_crowd": dict(t0=mid // 2, t1=mid + 1),
        "diurnal": {},
        "node_failure": dict(fail_epoch=mid, fail_node=0),
        "multi_hotspot": dict(theta=1.3, n_hotspots=3,
                              shift_every=max(scfg.n_epochs // 3, 1)),
        "keyspace_growth": {},
        "rack_failure_hotspot": dict(
            theta=1.2, shift_every=max(scfg.n_epochs // 3, 1),
            fail_epoch=mid, rack=(0, 1),
            recover_epoch=mid + 2 if mid + 2 < scfg.n_epochs else None,
        ),
        "ycsb_a": {},
        "stationary": {},
    }[name]


def _steady_epochs_per_s(drv, n_epochs: int, repeats: int = 1) -> float:
    """Steady-state epochs/s: re-drive the (already compiled) driver over
    the scenario's epochs via its real ``run()`` path, wall-clocked
    without trace/compile.  Best of ``repeats`` passes (per-pass noise on
    small CI boxes is large)."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        drv.run()
        best = min(best, time.perf_counter() - t0)
    return n_epochs / best


def run_matrix(scenarios, policies, quick: bool, *, service: str = "fixed",
               backend: str = "oracle", mesh=None, dist_cfg=None,
               period=DEFAULT_PERIOD, fused: bool = True,
               measure_steady: bool = False, verbose: bool = True):
    from repro.cluster import EpochDriver, make_policy, make_scenario, summarize

    rows = []
    for sname in scenarios:
        scfg = scenario_config(quick)
        for pname in policies:
            scen = make_scenario(sname, scfg, **scenario_kwargs(sname, scfg))
            drv = EpochDriver(scen, make_policy(pname),
                              cluster_config(quick, service, period),
                              backend=backend, mesh=mesh, dist_cfg=dist_cfg,
                              fused=fused)
            t0 = time.perf_counter()
            epochs = drv.run()
            wall = time.perf_counter() - t0
            row = summarize(epochs)
            row["wall_s"] = round(wall, 3)
            row["traces"] = drv.traces
            row["service"] = service
            row["backend"] = backend
            row["period"] = period
            row["fused"] = fused
            row["host_syncs"] = drv.host_syncs
            row["growth_events"] = drv.growth_events
            if drv.period_history:
                row["auto_periods"] = list(drv.period_history)
            if measure_steady and backend == "oracle":
                # the re-drive mutates driver state (fine for timing) but
                # runs AFTER the row's metrics are captured
                row["steady_eps"] = round(
                    _steady_epochs_per_s(drv, scfg.n_epochs), 2
                )
            rows.append(row)
            if verbose:
                eps = row.get("steady_eps")
                print(
                    f"{sname:20s} {pname:14s} imb {row['mean_imbalance']:5.2f} "
                    f"p99 {row['mean_p99']:6.1f} p50 {row['mean_p50']:6.1f} "
                    f"thr {row['mean_throughput']:.3f} "
                    f"ent {row['total_migration_entries']:6d} "
                    f"retries {row['total_retries']:4d} "
                    f"traces {row['traces']}"
                    + (f" steady {eps:7.2f} ep/s" if eps else "")
                )
    return rows


def check_acceptance(rows, *, quick: bool = False) -> list[str]:
    """The cluster-subsystem acceptance gates (see ISSUE/BENCHMARKS.md).

    ``quick`` (CI smoke sizes: 4 epochs) relaxes the splitting gate's
    imbalance comparison to "no worse" — at smoke scale a couple of
    control rounds cannot reliably separate the policies' imbalance
    means, but the keys-moved advantage and the compile-once property
    must hold at any size.
    """
    by = {(r["scenario"], r["policy"]): r for r in rows
          if r.get("backend", "oracle") == "oracle" and not r.get("profile")
          and not r.get("trace")
          and r.get("bench") not in ("replication", "replication_filter")}
    problems = []
    f = by.get(("shifting_hotspot", "frozen"))
    a = by.get(("shifting_hotspot", "full_adaptive"))
    if f and a:
        if not a["mean_imbalance"] < f["mean_imbalance"]:
            problems.append(
                f"full_adaptive imbalance {a['mean_imbalance']:.2f} !< "
                f"frozen {f['mean_imbalance']:.2f}"
            )
        if not a["mean_p99"] < f["mean_p99"]:
            problems.append(
                f"full_adaptive p99 {a['mean_p99']:.1f} !< "
                f"frozen {f['mean_p99']:.1f}"
            )
    # splitting gate: hot-subset control beats whole-range migration on
    # imbalance without moving more data
    m = by.get(("multi_hotspot", "migrate"))
    s = by.get(("multi_hotspot", "split_hot"))
    if m and s:
        ok = (s["mean_imbalance"] <= m["mean_imbalance"] if quick
              else s["mean_imbalance"] < m["mean_imbalance"])
        if not ok:
            problems.append(
                f"split_hot imbalance {s['mean_imbalance']:.2f} !< "
                f"migrate {m['mean_imbalance']:.2f}"
            )
        if not s["total_migration_entries"] <= m["total_migration_entries"]:
            problems.append(
                f"split_hot moved {s['total_migration_entries']} entries "
                f"!<= migrate {m['total_migration_entries']}"
            )
    for r in rows:
        expect = 1 + r.get("growth_events", 0)
        if r["traces"] != expect:
            problems.append(
                f"{r['scenario']}/{r['policy']}: epoch step traced "
                f"{r['traces']}x (expected {expect})"
            )
    return problems


def run_profile(quick: bool) -> tuple[list[dict], list[str]]:
    """The epoch-pipeline profile: fused vs per-epoch driver, same scenario,
    same config — compile vs steady-state wall clock and host-sync counts.

    The comparison fuses the **whole run into one control period**
    (``period = n_epochs``) for both drivers: policy decisions and pull
    costs are then identical on both sides, so the measured delta is
    purely the device-resident pipeline (scan + donated buffers + one
    host sync per period vs one per epoch).

    Returns (rows, problems): the ratio gate (fused steady-state epochs/s
    ``>= PROFILE_RATIO_GATE x`` per-epoch) plus a deterministic host-sync
    gate (fused must make strictly fewer device->host round-trips) are
    the CI smoke assertions for the device-resident pipeline.
    """
    from repro.cluster import EpochDriver, make_policy, make_scenario

    scfg = scenario_config(quick)
    period = scfg.n_epochs
    rows, problems = [], []
    for pname in PROFILE_POLICIES:
        measured = {}
        for fused in (True, False):
            scen = make_scenario(
                PROFILE_SCENARIO, scfg,
                **scenario_kwargs(PROFILE_SCENARIO, scfg))
            drv = EpochDriver(scen, make_policy(pname),
                              cluster_config(quick, period=period),
                              fused=fused)
            t0 = time.perf_counter()
            drv.run()
            wall = time.perf_counter() - t0
            syncs_run = drv.host_syncs
            steady = _steady_epochs_per_s(drv, scfg.n_epochs, repeats=3)
            row = {
                "profile": True,
                "scenario": PROFILE_SCENARIO,
                "policy": pname,
                "fused": fused,
                "period": period,
                "epochs": scfg.n_epochs,
                "wall_s": round(wall, 3),
                "compile_s": round(wall - scfg.n_epochs / steady, 3),
                "steady_eps": round(steady, 2),
                "host_syncs": syncs_run,
                "host_syncs_per_epoch": round(syncs_run / scfg.n_epochs, 2),
                "traces": drv.traces,
            }
            measured[fused] = row
            rows.append(row)
            print(
                f"[profile] {pname:14s} {'fused' if fused else 'epoch':5s} "
                f"P={period} wall {row['wall_s']:6.2f}s "
                f"(compile ~{row['compile_s']:5.2f}s) "
                f"steady {row['steady_eps']:8.2f} epochs/s "
                f"syncs/epoch {row['host_syncs_per_epoch']:5.2f} "
                f"traces {row['traces']}"
            )
        gate = PROFILE_RATIO_GATE_QUICK if quick else PROFILE_RATIO_GATE
        ratio = measured[True]["steady_eps"] / max(measured[False]["steady_eps"], 1e-9)
        if ratio < gate:
            problems.append(
                f"profile: fused steady epochs/s only {ratio:.2f}x the "
                f"per-epoch driver on {PROFILE_SCENARIO}/{pname} "
                f"(gate {gate}x)"
            )
        if not measured[True]["host_syncs"] < measured[False]["host_syncs"]:
            problems.append(
                f"profile: fused driver made {measured[True]['host_syncs']} "
                f"host syncs !< per-epoch {measured[False]['host_syncs']} "
                f"on {PROFILE_SCENARIO}/{pname}"
            )
    return rows, problems


# the --trace pair: the adaptive-gate scenario under its winning policy
TRACE_SCENARIO = "shifting_hotspot"
TRACE_POLICY = "full_adaptive"
TRACE_ARTIFACT = "TRACE_balance.json"


def run_trace(quick: bool, out: str = TRACE_ARTIFACT
              ) -> tuple[list[dict], list[str]]:
    """The telemetry parity column (PR 7 acceptance assertions).

    Runs the adaptive-gate pair twice — ``telemetry=None`` and with span
    sampling on — and asserts the three hard telemetry contracts:

    * **off-mode bit-parity**: the telemetry-on run's ``EpochMetrics``
      rows equal the telemetry-off rows field-for-field (tracing is a
      pure observer — it may not perturb the metric stream);
    * **one compiled step**: span collection lives inside the fused scan
      body, so ``drv.traces`` must stay 1;
    * **exact reconstruction**: every sampled span's latency bucket
      decomposition sums back to its DES closed-loop latency with zero
      float64 error (``TelemetryRecorder.verify_exact() == 0.0``).

    Writes the Chrome-trace artifact to ``out`` and returns
    (rows, problems).
    """
    import dataclasses

    from repro.cluster import (
        EpochDriver, TelemetryConfig, make_policy, make_scenario,
    )

    scfg = scenario_config(quick)
    kw = scenario_kwargs(TRACE_SCENARIO, scfg)
    # sample aggressively at smoke sizes so the parity run records >0
    # spans; full size uses the default 1/64 production rate
    tcfg = TelemetryConfig(sample_rate=1 / 4 if quick else 1 / 64)

    def drive(tel):
        scen = make_scenario(TRACE_SCENARIO, scfg, **kw)
        drv = EpochDriver(scen, make_policy(TRACE_POLICY),
                          dataclasses.replace(cluster_config(quick),
                                              telemetry=tel))
        return drv, drv.run()

    _, base = drive(None)
    drv, traced = drive(tcfg)

    problems = []
    if [r.to_row() for r in base] != [r.to_row() for r in traced]:
        problems.append(
            "trace: telemetry-on EpochMetrics rows differ from the "
            "telemetry-off run (tracing perturbed the metric stream)")
    if drv.traces != 1:
        problems.append(
            f"trace: epoch step traced {drv.traces}x with sampling on "
            "(expected 1)")
    err = drv.telemetry.verify_exact()
    if err != 0.0:
        problems.append(
            f"trace: span latency reconstruction off by {err!r} "
            "(must be exactly 0.0)")
    n_spans = drv.telemetry.span_count
    if n_spans == 0:
        problems.append("trace: sampling enabled but zero spans recorded")

    path = drv.telemetry.write_chrome_trace(out)
    summ = drv.telemetry.summary()
    row = {
        "trace": True,
        "scenario": TRACE_SCENARIO,
        "policy": TRACE_POLICY,
        "sample_rate": tcfg.sample_rate,
        "spans": n_spans,
        "n_sampled": summ["spans_sampled"],
        "reconstruction_max_err": err,
        "traces": drv.traces,
        "parity": not problems,
        "artifact": path,
    }
    print(
        f"[trace] {TRACE_SCENARIO}/{TRACE_POLICY} spans {n_spans} "
        f"(sampled {summ['spans_sampled']}) reconstruction err {err!r} "
        f"traces {drv.traces} -> {path}"
    )
    return [row], problems


def run_dist_parity(quick: bool) -> list[dict]:
    """Dist-backend parity column in a subprocess (forced 8-device mesh).

    jax pins the host device count at first init, so the parent process
    (which already initialized jax for the oracle matrix) cannot host the
    mesh itself.
    """
    env = {
        **os.environ,
        "XLA_FLAGS": "--xla_force_host_platform_device_count=8 "
        + os.environ.get("XLA_FLAGS", ""),
        "JAX_PLATFORMS": "cpu",
    }
    cmd = [sys.executable, "-m", "benchmarks.balance_bench", "--dist-worker"]
    if quick:
        cmd.append("--quick")
    r = subprocess.run(cmd, env=env, capture_output=True, text=True)
    if r.returncode != 0:
        print(r.stdout)
        print(r.stderr, file=sys.stderr)
        raise RuntimeError("dist parity worker failed")
    payload = json.loads(r.stdout.splitlines()[-1])
    return payload["rows"]


def dist_worker(quick: bool) -> int:
    import jax
    from repro.core import DistConfig

    mesh = jax.make_mesh((8,), ("data",))
    # a tight per-(source,target) queue bound so the flash crowd actually
    # exercises switch queue pressure: overflowing queries are dropped
    # and counted as client retries (the quantity this column reports)
    dist_cfg = DistConfig(bucket_cap=16 if quick else 24)
    rows = run_matrix([DIST_SCENARIO], list(DIST_POLICIES), quick,
                      backend="dist", mesh=mesh, dist_cfg=dist_cfg,
                      verbose=False)
    rows.append(_dist_growth_row(mesh, quick))
    print(json.dumps({"rows": rows}))
    return 0


def _dist_growth_row(mesh, quick: bool) -> dict:
    """keyspace_growth under capacity pressure on the dist backend: the
    pool must actually grow mid-run, and growth must cost exactly one
    re-specialization of the fused period program
    (``traces == 1 + growth_events`` — checked by the --dist gate)."""
    from repro.cluster import (ClusterConfig, EpochDriver, ScenarioConfig,
                               make_policy, make_scenario, summarize)

    scfg = ScenarioConfig(n_epochs=6 if quick else 10, epoch_ops=512,
                          n_records=2048, read_ratio=0.3, value_dim=2,
                          seed=1)
    scen = make_scenario("keyspace_growth", scfg)
    drv = EpochDriver(
        scen, make_policy("full_adaptive"),
        ClusterConfig(num_nodes=8, num_ranges=8, n_slots=8, replication=1,
                      r_max=2, capacity=64, split_overflow=True,
                      report_every=2),
        backend="dist", mesh=mesh)
    epochs = drv.run()
    row = summarize(epochs)
    row.update({
        "scenario": "keyspace_growth",
        "bench": "dist_growth",
        "backend": "dist",
        "fused": True,
        "traces": drv.traces,
        "growth_events": drv.growth_events,
        "host_syncs": drv.host_syncs,
    })
    return row


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="tiny sizes (CI smoke)")
    ap.add_argument("--scenarios", default=",".join(DEFAULT_SCENARIOS))
    ap.add_argument("--policies", default=",".join(DEFAULT_POLICIES))
    ap.add_argument("--service", default="fixed",
                    choices=("fixed", "lognormal", "pareto"),
                    help="per-hop service-time distribution (ServiceModel)")
    ap.add_argument("--dist", action="store_true",
                    help="also run the dist-backend parity column "
                         "(8-device host mesh subprocess)")
    ap.add_argument("--dist-worker", action="store_true",
                    help=argparse.SUPPRESS)  # internal: the forked mesh run
    ap.add_argument("--period", default=str(DEFAULT_PERIOD),
                    help="control-pull cadence = fused scan length "
                         f"(default {DEFAULT_PERIOD}); 'auto' adapts the "
                         "cadence to report-to-report load drift")
    ap.add_argument("--per-epoch", action="store_true",
                    help="run the per-epoch reference driver instead of "
                         "the fused period pipeline")
    ap.add_argument("--profile", action="store_true",
                    help="also run the fused vs per-epoch pipeline profile "
                         "(steady-state epochs/s + host-sync counts, with "
                         "the ratio gate)")
    ap.add_argument("--replication", action="store_true",
                    help="also run the three-mode replication comparison "
                         "(eventual/chain/craq tail latencies + gates)")
    ap.add_argument("--trace", action="store_true",
                    help="also run the telemetry parity column and emit a "
                         "Chrome-trace artifact (see --trace-out)")
    ap.add_argument("--trace-out", default=TRACE_ARTIFACT,
                    help=f"Chrome-trace artifact path (default "
                         f"{TRACE_ARTIFACT})")
    ap.add_argument("--json", default=None, help="write rows to this path")
    ap.add_argument("--no-check", action="store_true",
                    help="skip the acceptance gate (exploratory runs)")
    args = ap.parse_args(argv)

    if args.dist_worker:
        return dist_worker(args.quick)

    period = args.period if args.period == "auto" else int(args.period)
    scenarios = [s for s in args.scenarios.split(",") if s]
    policies = [p for p in args.policies.split(",") if p]
    rows = run_matrix(scenarios, policies, args.quick, service=args.service,
                      period=period, fused=not args.per_epoch,
                      measure_steady=True)

    profile_problems: list[str] = []
    if args.profile:
        profile_rows, profile_problems = run_profile(args.quick)
        rows.extend(profile_rows)

    trace_problems: list[str] = []
    if args.trace:
        trace_rows, trace_problems = run_trace(args.quick, args.trace_out)
        rows.extend(trace_rows)

    replication_problems: list[str] = []
    if args.replication:
        from repro.replication.bench import (
            check_filter_arm, check_replication, run_filter_arm,
            run_replication_matrix,
        )
        repl_rows = run_replication_matrix(args.quick)
        replication_problems = check_replication(repl_rows)
        filter_rows = run_filter_arm(args.quick)
        replication_problems += check_filter_arm(filter_rows)
        rows.extend(repl_rows)
        rows.extend(filter_rows)

    dist_problems: list[str] = []
    if args.dist:
        dist_rows = run_dist_parity(args.quick)
        for r in dist_rows:
            print(
                f"[dist] {r['scenario']:14s} {r['policy']:14s} "
                f"imb {r['mean_imbalance']:5.2f} p99 {r['mean_p99']:6.1f} "
                f"retries {r['total_retries']:4d} "
                f"({r['total_retries'] / max(r['epochs'], 1):.1f}/epoch) "
                f"traces {r['traces']} grows {r.get('growth_events', 0)}"
            )
            expect = 1 + r.get("growth_events", 0)
            if r["traces"] != expect:
                dist_problems.append(
                    f"dist {r['scenario']}/{r['policy']}: traces "
                    f"{r['traces']} != 1 + growth_events ({expect})"
                )
        grow = [r for r in dist_rows if r.get("bench") == "dist_growth"]
        if grow and grow[0]["growth_events"] < 1:
            dist_problems.append(
                "keyspace_growth --dist never grew the pool under "
                "capacity pressure"
            )
        rows.extend(dist_rows)

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"quick": args.quick, "service": args.service,
                       "rows": rows}, f, indent=1)
        print(f"wrote {args.json} ({len(rows)} rows)")
        from benchmarks import history
        history.append("balance", {"quick": args.quick,
                                   "service": args.service, "rows": rows})

    if not args.no_check:
        problems = (check_acceptance(rows, quick=args.quick)
                    + profile_problems + trace_problems
                    + replication_problems + dist_problems)
        if problems:
            print("ACCEPTANCE FAILED:")
            for p in problems:
                print("  -", p)
            return 1
        gates = []
        if "shifting_hotspot" in scenarios:
            gates.append("full_adaptive < frozen on imbalance AND p99")
        if "multi_hotspot" in scenarios:
            gates.append("split_hot < migrate on imbalance at <= entries moved")
        gates.append("all steps compiled once")
        if args.dist:
            gates.append("dist: traces == 1 + growth_events, pool grows "
                         "under keyspace_growth capacity pressure")
        if args.profile:
            g = PROFILE_RATIO_GATE_QUICK if args.quick else PROFILE_RATIO_GATE
            gates.append(
                f"fused steady epochs/s >= {g}x per-epoch at fewer syncs")
        if args.trace:
            gates.append(
                "telemetry: off-mode bit-parity, one compiled step, "
                "exact span reconstruction")
        if args.replication:
            gates.append(
                "craq clean-read p99 <= chain tail-read p99 on read-heavy "
                "diurnal; dirty bounces only (and always) under craq writes")
        print("acceptance: " + "; ".join(gates))
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Coordination-engine benchmark: vectorized DES vs the heapq oracle.

Produces the perf-trajectory numbers recorded in ``BENCH_coordination.json``:

* single-scenario closed-loop and open-loop wall-clock at a given batch
  size (B=8192 by default) for both engines,
* the fused paper sweep (every fig13a/fig13bc/tables12 scenario — 57
  (workload × mode) lanes — in **one** engine call) vs the oracle run
  scenario-by-scenario,
* a 1M-op closed-loop sweep across all three coordination modes
  (vectorized only; the oracle would take minutes).

Run via ``python -m benchmarks.run --json BENCH_coordination.json``.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import core as C
from repro.data.ycsb import WorkloadConfig, run_phase

from benchmarks.paper_tables import (
    N_CLIENTS,
    N_NODES,
    N_RANGES,
    REPLICATION,
    build_scenarios,
    fig13a_workloads,
    fig13bc_workloads,
    tables12_workloads,
)


def _sweep_workloads(n_ops: int):
    """The full paper-suite workload list — the same grids the figures use."""
    return (fig13a_workloads(n_ops) + fig13bc_workloads(n_ops)
            + tables12_workloads(n_ops))


def _mixed_plan(n_ops: int, mode: str = C.SERVER_DRIVEN):
    wcfg = WorkloadConfig(n_ops=n_ops, read_ratio=0.5, update_ratio=0.5)
    opcodes, keys, end_keys, values, arrivals = run_phase(wcfg)
    d = C.make_directory(N_RANGES, N_NODES, REPLICATION)
    q = C.make_queries(jnp.asarray(keys), jnp.asarray(opcodes),
                       jnp.asarray(values), jnp.asarray(end_keys))
    dec, d = C.route(d, q)
    plan = C.plan_hops(q, dec, mode, C.LatencyModel(),
                       rng=jax.random.PRNGKey(0), num_nodes=N_NODES)
    return plan, arrivals


def _wall(fn, *args, repeats: int = 3, **kw):
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return best, out


def bench_engine(n_ops: int = 8192, *, include_reference: bool = True,
                 include_1m: bool = True, backend: str | None = None):
    """Returns (rows, wall) — CSV rows plus raw wall-clock seconds."""
    rows: list[tuple[str, float, str]] = []
    # resolve exactly like the simulate calls below will (incl. env override)
    wall: dict = {"backend": C.des._resolve_backend(backend)}

    # --- single scenario, closed + open loop -------------------------------
    plan, arrivals = _mixed_plan(n_ops)
    arr = jnp.asarray(arrivals)

    t_vec, (lat_v, mk_v) = _wall(
        C.simulate_closed_loop, plan,
        n_clients=N_CLIENTS, num_nodes=N_NODES, backend=backend)
    wall[f"closed_B{n_ops}_vectorized_s"] = t_vec
    derived = f"makespan={float(mk_v):.0f}"
    if include_reference:
        t_ref, (lat_r, mk_r) = _wall(
            C.simulate_closed_loop_reference, plan,
            n_clients=N_CLIENTS, num_nodes=N_NODES, repeats=1)
        wall[f"closed_B{n_ops}_reference_s"] = t_ref
        exact = bool(np.array_equal(np.asarray(lat_v), np.asarray(lat_r)))
        derived += f";speedup_vs_reference={t_ref / t_vec:.1f}x;bitexact={exact}"
    rows.append((f"des/closed_loop/B{n_ops}", t_vec * 1e6 / n_ops, derived))

    t_vec_o, (lat_vo, mk_vo) = _wall(
        C.simulate, plan, arr, num_nodes=N_NODES, backend=backend)
    wall[f"open_B{n_ops}_vectorized_s"] = t_vec_o
    derived = f"makespan={float(mk_vo):.0f}"
    if include_reference:
        t_ref_o, (lat_ro, mk_ro) = _wall(
            C.simulate_reference, plan, arr, num_nodes=N_NODES, repeats=1)
        wall[f"open_B{n_ops}_reference_s"] = t_ref_o
        exact = bool(np.array_equal(np.asarray(lat_vo), np.asarray(lat_ro)))
        derived += f";speedup_vs_reference={t_ref_o / t_vec_o:.1f}x;bitexact={exact}"
    rows.append((f"des/open_loop/B{n_ops}", t_vec_o * 1e6 / n_ops, derived))

    # --- fused paper sweep (the hot path this engine exists for) -----------
    _, plans = build_scenarios(_sweep_workloads(n_ops))
    S = len(plans)
    stacked = C.stack_plans(plans)
    t_sweep, (lat_s, mk_s) = _wall(
        C.simulate_closed_loop, stacked,
        n_clients=N_CLIENTS, num_nodes=N_NODES, backend=backend)
    wall[f"sweep{S}_B{n_ops}_vectorized_s"] = t_sweep
    derived = f"scenarios={S};per_scenario_ms={t_sweep / S * 1e3:.2f}"
    if include_reference:
        t0 = time.perf_counter()
        for i, p in enumerate(plans):
            lat_r, mk_r = C.simulate_closed_loop_reference(
                p, n_clients=N_CLIENTS, num_nodes=N_NODES)
            assert np.asarray(mk_s)[i] == np.asarray(mk_r)
        t_refsweep = time.perf_counter() - t0
        wall[f"sweep{S}_B{n_ops}_reference_s"] = t_refsweep
        derived += f";speedup_vs_reference={t_refsweep / t_sweep:.1f}x"
    rows.append((f"des/fused_sweep/S{S}/B{n_ops}", t_sweep * 1e6 / (S * n_ops),
                 derived))

    # --- 1M-op closed-loop sweep across all three modes ---------------------
    if include_1m:
        n_big = 1_000_000
        wcfg = WorkloadConfig(n_ops=n_big, read_ratio=0.5, update_ratio=0.5)
        opcodes, keys, end_keys, values, _ = run_phase(wcfg)
        d = C.make_directory(N_RANGES, N_NODES, REPLICATION)
        q = C.make_queries(jnp.asarray(keys), jnp.asarray(opcodes),
                           jnp.asarray(values), jnp.asarray(end_keys))
        dec, d = C.route(d, q)
        big = C.stack_plans([
            C.plan_hops(q, dec, m, C.LatencyModel(),
                        rng=jax.random.PRNGKey(0), num_nodes=N_NODES)
            for m in C.MODES
        ])
        t0 = time.perf_counter()
        lat_b, mk_b = C.simulate_closed_loop(
            big, n_clients=N_CLIENTS, num_nodes=N_NODES, backend=backend)
        t_big = time.perf_counter() - t0
        wall["sweep3_B1000000_vectorized_s"] = t_big
        rows.append((
            "des/fused_sweep/S3/B1000000", t_big * 1e6 / (3 * n_big),
            f"wall_s={t_big:.2f};makespans=" + ",".join(
                f"{float(x):.0f}" for x in np.asarray(mk_b)),
        ))
    return rows, wall

"""Kernel microbenchmarks (CPU wall-clock of the jnp refs; the Pallas paths
run interpret=True so their wall-times are *not* TPU-indicative — the TPU
performance story lives in the dry-run roofline, EXPERIMENTS.md §Roofline).

Reported: us_per_call of the jitted oracle path at production-ish shapes,
plus the derived routing throughput (the paper's headline metric is ops/s
through the coordination layer).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import core as C
from repro.kernels.range_match.ops import range_match
from repro.kernels.decode_attn.ops import decode_attn
from repro.kernels.ssd_chunk.ops import ssd_scan

RNG = np.random.default_rng(0)


def _time(fn, *args, iters=20, warmup=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def bench_range_match():
    rows = []
    for B in (4096, 65536):
        for R in (128, 1024):
            d = C.make_directory(R, 16, 3)
            keys = jnp.asarray(RNG.integers(0, 2**32 - 2, B), jnp.uint32)
            ops = jnp.asarray(RNG.integers(0, 2, B), jnp.int32)
            fn = jax.jit(lambda dd, kk, oo: range_match(dd, kk, oo, use_pallas=False))
            us = _time(fn, d, keys, ops)
            rows.append((f"range_match/B{B}/R{R}", us, f"{B / us:.1f}Mops_s"))

    # Pallas path next to the oracle.  interpret resolves per backend
    # (compiled on TPU, interpreter elsewhere) — off-TPU wall-times are
    # interpreter times and are labelled as such, they only guard against
    # regressions in the kernel's launch path, not TPU perf.
    from repro.kernels.range_match.ops import default_interpret

    interp = default_interpret()
    tag = "interpret" if interp else "compiled"
    for B, R in ((4096, 128),) if interp else ((4096, 128), (65536, 1024)):
        d = C.make_directory(R, 16, 3)
        keys = jnp.asarray(RNG.integers(0, 2**32 - 2, B), jnp.uint32)
        ops = jnp.asarray(RNG.integers(0, 2, B), jnp.int32)
        pf = lambda dd, kk, oo: range_match(dd, kk, oo, use_pallas=True)
        us = _time(pf, d, keys, ops, iters=3 if interp else 20,
                   warmup=1 if interp else 3)
        out_p = pf(d, keys, ops)
        out_r = range_match(d, keys, ops, use_pallas=False)
        agree = all(bool(jnp.array_equal(a, b)) for a, b in zip(out_p, out_r))
        rows.append((f"range_match_pallas/{tag}/B{B}/R{R}", us,
                     f"{B / us:.1f}Mops_s;agrees_with_oracle={agree}"))

    # load-aware p2c read spreading (the repro.cluster adaptive hot path)
    from repro.kernels.range_match.ops import range_match_spread

    B, R = 4096, 128
    d = C.make_directory(R, 16, 3, r_max=5)
    keys = jnp.asarray(RNG.integers(0, 2**32 - 2, B), jnp.uint32)
    ops = jnp.asarray(RNG.integers(0, 2, B), jnp.int32)
    load = jnp.asarray(RNG.integers(0, 100, 16), jnp.uint32)
    rng = jax.random.PRNGKey(0)
    sf = lambda dd, kk, oo: range_match_spread(dd, kk, oo, load, rng,
                                               use_pallas=False)
    us = _time(sf, d, keys, ops)
    rows.append((f"range_match_spread/B{B}/R{R}", us, f"{B / us:.1f}Mops_s"))
    pf2 = lambda dd, kk, oo: range_match_spread(dd, kk, oo, load, rng,
                                                use_pallas=True)
    us = _time(pf2, d, keys, ops, iters=3 if interp else 20,
               warmup=1 if interp else 3)
    agree = all(
        bool(jnp.array_equal(a, b))
        for a, b in zip(pf2(d, keys, ops), sf(d, keys, ops))
    )
    rows.append((f"range_match_spread_pallas/{tag}/B{B}/R{R}", us,
                 f"{B / us:.1f}Mops_s;agrees_with_oracle={agree}"))
    return rows


def _rand_slabs(n_nodes, cap):
    """(N, cap) sorted per-node slab keys, ~half full, EMPTY tail padded."""
    out = np.full((n_nodes, cap), 0xFFFFFFFF, np.uint32)
    for n in range(n_nodes):
        k = np.unique(RNG.integers(1, 2**32 - 2, cap // 2).astype(np.uint32))
        out[n, : len(k)] = np.sort(k)
    return jnp.asarray(out)


def _time_group(fns, args, reps=7, iters=2):
    """Round-robin timing: every rep times each candidate once, and each
    candidate keeps its min.  Interleaving means slow windows (scheduler
    noise, thermal drift on shared/single-core hosts) hit all candidates
    alike instead of biasing whichever ran later; the min discards them."""
    best = [float("inf")] * len(fns)
    for fn in fns:
        jax.block_until_ready(fn(*args))  # compile + warm
    for _ in range(reps):
        for i, fn in enumerate(fns):
            t0 = time.perf_counter()
            for _ in range(iters):
                out = fn(*args)
            jax.block_until_ready(out)
            best[i] = min(best[i], (time.perf_counter() - t0) / iters)
    return [b * 1e6 for b in best]  # us


def bench_range_match_apply():
    """Fused one-kernel route→apply vs the pre-PR route→apply pipeline.

    Baseline = what serving looked like before the fusion: the Pallas
    routing kernel, then ``store.apply_routed``'s read path — every
    shard runs a masked full-batch slab probe and a one-hot owner
    combine picks the serving node's answer (N×B probe work).  The
    fused kernel routes and probes *only the serving node's slab* in
    one pass (B probe work), so the win scales with N.  The derived
    ``route_apply_ratio`` is the acceptance gate (>= 1.2x at the full
    size); ``agrees_with_ref`` is bit-parity against the jnp ref.

    A second row times the split ``fuse=False`` path — the *same* tile
    formulation as two back-to-back Pallas kernels.  Off-TPU that ratio
    is ~1.0 by construction (the interpreter lowers kernel bodies
    in-graph, so a launch costs nothing); on TPU it prices the HBM
    roundtrip + second launch that the fusion deletes.  It is a
    diagnostic, not the gate.
    """
    from repro.kernels.range_match.ops import (
        range_match_apply, range_match_spread_dirty, default_interpret,
    )

    rows = []
    interp = default_interpret()
    tag = "interpret" if interp else "compiled"
    N, r_max = 32, 5  # scale-out size: the fused win grows with N
    sizes = ((4096, 128, 512),) if interp else (
        (4096, 128, 512), (65536, 1024, 4096),
    )
    for B, R, cap in sizes:
        d = C.make_directory(R, N, 3, r_max=r_max)
        slabs = _rand_slabs(N, cap)
        keys = np.asarray(RNG.integers(0, 2**32 - 2, B), np.uint32)
        # half the batch are real store hits so found isn't all-miss
        keys[: B // 2] = np.asarray(slabs)[
            RNG.integers(0, N, B // 2), RNG.integers(0, cap // 3, B // 2)
        ]
        keys = jnp.asarray(keys, jnp.uint32)
        ops = jnp.asarray(RNG.integers(0, 2, B), jnp.int32)
        load = jnp.asarray(RNG.integers(0, 100, N), jnp.uint32)
        dirty = jnp.asarray(RNG.integers(0, 2, (R, r_max)).astype(bool))
        rng = jax.random.PRNGKey(0)

        fused = lambda dd, kk, oo: range_match_apply(
            dd, kk, oo, load, dirty, slabs, rng, use_pallas=True, fuse=True)
        split = lambda dd, kk, oo: range_match_apply(
            dd, kk, oo, load, dirty, slabs, rng, use_pallas=True, fuse=False)

        # pre-PR pipeline: Pallas route, then apply_routed's read path
        # (all-shard masked slab probe + one-hot owner combine)
        @jax.jit
        def _apply_sweep(target, qkeys):
            def one_shard(slab):
                pos = jnp.minimum(jnp.searchsorted(slab, qkeys), cap - 1)
                fnd = (slab[pos] == qkeys) & (qkeys != jnp.uint32(0xFFFFFFFF))
                return pos, fnd
            pos_n, fnd_n = jax.vmap(one_shard)(slabs)            # (N, B)
            owner = jax.nn.one_hot(
                jnp.clip(target, 0, N - 1), N, dtype=jnp.int32)  # (B, N)
            slot = jnp.einsum("nb,bn->b", pos_n, owner)
            found = jnp.einsum("nb,bn->b", fnd_n.astype(jnp.int32), owner) > 0
            return slot, found & (target >= 0)

        def route_then_apply(dd, kk, oo):
            ridx, target, chain, picked, bounced = range_match_spread_dirty(
                dd, kk, oo, load, dirty, rng, use_pallas=True)
            slot, found = _apply_sweep(target, kk)
            return ridx, target, chain, picked, bounced, slot, found

        us_f, us_p, us_2 = _time_group(
            [fused, route_then_apply, split], (d, keys, ops))
        out_f = fused(d, keys, ops)
        out_r = range_match_apply(d, keys, ops, load, dirty, slabs, rng,
                                  use_pallas=False)
        out_p = route_then_apply(d, keys, ops)
        agree = all(bool(jnp.array_equal(a, b)) for a, b in zip(out_f, out_r))
        agree_p = (bool(jnp.array_equal(out_f[5], out_p[5]))
                   and bool(jnp.array_equal(out_f[6], out_p[6])))
        rows.append((f"range_match_apply/{tag}/B{B}/R{R}/C{cap}", us_f,
                     f"{B / us_f:.1f}Mops_s;route_apply_ratio={us_p / us_f:.2f}x;"
                     f"agrees_with_ref={agree}"))
        rows.append((f"range_match_route_then_apply/{tag}/B{B}/R{R}/C{cap}",
                     us_p, f"{B / us_p:.1f}Mops_s;baseline=pre_fusion_pipeline;"
                     f"agrees_with_fused={agree_p}"))
        rows.append((f"range_match_apply_split/{tag}/B{B}/R{R}/C{cap}",
                     us_2, f"{B / us_2:.1f}Mops_s;"
                     f"split_ratio={us_2 / us_f:.2f}x;diagnostic=same_tiles"))
    return rows


def bench_decode_attn():
    rows = []
    for (B, S, Hq, Hkv, D) in [(8, 4096, 32, 8, 128), (32, 2048, 8, 2, 64)]:
        q = jnp.asarray(RNG.normal(size=(B, Hq, D)), jnp.bfloat16)
        k = jnp.asarray(RNG.normal(size=(B, S, Hkv, D)), jnp.bfloat16)
        v = jnp.asarray(RNG.normal(size=(B, S, Hkv, D)), jnp.bfloat16)
        lengths = jnp.full((B,), S, jnp.int32)
        fn = jax.jit(lambda *a: decode_attn(*a, use_pallas=False))
        us = _time(fn, q, k, v, lengths)
        flops = 2 * 2 * B * Hq * S * D  # qk + pv
        rows.append((f"decode_attn/B{B}S{S}H{Hq}", us, f"{flops / us / 1e3:.1f}GFLOPs"))
    return rows


def bench_ssd():
    rows = []
    for (B, T, H, P, N, chunk) in [(2, 2048, 32, 64, 128, 128)]:
        x = jnp.asarray(RNG.normal(size=(B, T, H, P)), jnp.float32)
        dt = jnp.asarray(RNG.uniform(0.001, 0.1, (B, T, H)), jnp.float32)
        A = jnp.asarray(-RNG.uniform(0.5, 2.0, H), jnp.float32)
        Bm = jnp.asarray(RNG.normal(size=(B, T, N)), jnp.float32)
        Cm = jnp.asarray(RNG.normal(size=(B, T, N)), jnp.float32)
        fn = jax.jit(lambda *a: ssd_scan(*a, chunk=chunk, use_pallas=False))
        us = _time(fn, x, dt, A, Bm, Cm, iters=5)
        rows.append((f"ssd_scan/B{B}T{T}H{H}", us, f"chunk{chunk}"))
    return rows

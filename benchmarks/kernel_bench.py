"""Kernel microbenchmarks (CPU wall-clock of the jnp refs; the Pallas paths
run interpret=True so their wall-times are *not* TPU-indicative — the TPU
performance story lives in the dry-run roofline, EXPERIMENTS.md §Roofline).

Reported: us_per_call of the jitted oracle path at production-ish shapes,
plus the derived routing throughput (the paper's headline metric is ops/s
through the coordination layer).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import core as C
from repro.kernels.range_match.ops import range_match
from repro.kernels.decode_attn.ops import decode_attn
from repro.kernels.ssd_chunk.ops import ssd_scan

RNG = np.random.default_rng(0)


def _time(fn, *args, iters=20, warmup=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def bench_range_match():
    rows = []
    for B in (4096, 65536):
        for R in (128, 1024):
            d = C.make_directory(R, 16, 3)
            keys = jnp.asarray(RNG.integers(0, 2**32 - 2, B), jnp.uint32)
            ops = jnp.asarray(RNG.integers(0, 2, B), jnp.int32)
            fn = jax.jit(lambda dd, kk, oo: range_match(dd, kk, oo, use_pallas=False))
            us = _time(fn, d, keys, ops)
            rows.append((f"range_match/B{B}/R{R}", us, f"{B / us:.1f}Mops_s"))

    # Pallas path next to the oracle.  interpret resolves per backend
    # (compiled on TPU, interpreter elsewhere) — off-TPU wall-times are
    # interpreter times and are labelled as such, they only guard against
    # regressions in the kernel's launch path, not TPU perf.
    from repro.kernels.range_match.ops import default_interpret

    interp = default_interpret()
    tag = "interpret" if interp else "compiled"
    for B, R in ((4096, 128),) if interp else ((4096, 128), (65536, 1024)):
        d = C.make_directory(R, 16, 3)
        keys = jnp.asarray(RNG.integers(0, 2**32 - 2, B), jnp.uint32)
        ops = jnp.asarray(RNG.integers(0, 2, B), jnp.int32)
        pf = lambda dd, kk, oo: range_match(dd, kk, oo, use_pallas=True)
        us = _time(pf, d, keys, ops, iters=3 if interp else 20,
                   warmup=1 if interp else 3)
        out_p = pf(d, keys, ops)
        out_r = range_match(d, keys, ops, use_pallas=False)
        agree = all(bool(jnp.array_equal(a, b)) for a, b in zip(out_p, out_r))
        rows.append((f"range_match_pallas/{tag}/B{B}/R{R}", us,
                     f"{B / us:.1f}Mops_s;agrees_with_oracle={agree}"))

    # load-aware p2c read spreading (the repro.cluster adaptive hot path)
    from repro.kernels.range_match.ops import range_match_spread

    B, R = 4096, 128
    d = C.make_directory(R, 16, 3, r_max=5)
    keys = jnp.asarray(RNG.integers(0, 2**32 - 2, B), jnp.uint32)
    ops = jnp.asarray(RNG.integers(0, 2, B), jnp.int32)
    load = jnp.asarray(RNG.integers(0, 100, 16), jnp.uint32)
    rng = jax.random.PRNGKey(0)
    sf = lambda dd, kk, oo: range_match_spread(dd, kk, oo, load, rng,
                                               use_pallas=False)
    us = _time(sf, d, keys, ops)
    rows.append((f"range_match_spread/B{B}/R{R}", us, f"{B / us:.1f}Mops_s"))
    pf2 = lambda dd, kk, oo: range_match_spread(dd, kk, oo, load, rng,
                                                use_pallas=True)
    us = _time(pf2, d, keys, ops, iters=3 if interp else 20,
               warmup=1 if interp else 3)
    agree = all(
        bool(jnp.array_equal(a, b))
        for a, b in zip(pf2(d, keys, ops), sf(d, keys, ops))
    )
    rows.append((f"range_match_spread_pallas/{tag}/B{B}/R{R}", us,
                 f"{B / us:.1f}Mops_s;agrees_with_oracle={agree}"))
    return rows


def bench_decode_attn():
    rows = []
    for (B, S, Hq, Hkv, D) in [(8, 4096, 32, 8, 128), (32, 2048, 8, 2, 64)]:
        q = jnp.asarray(RNG.normal(size=(B, Hq, D)), jnp.bfloat16)
        k = jnp.asarray(RNG.normal(size=(B, S, Hkv, D)), jnp.bfloat16)
        v = jnp.asarray(RNG.normal(size=(B, S, Hkv, D)), jnp.bfloat16)
        lengths = jnp.full((B,), S, jnp.int32)
        fn = jax.jit(lambda *a: decode_attn(*a, use_pallas=False))
        us = _time(fn, q, k, v, lengths)
        flops = 2 * 2 * B * Hq * S * D  # qk + pv
        rows.append((f"decode_attn/B{B}S{S}H{Hq}", us, f"{flops / us / 1e3:.1f}GFLOPs"))
    return rows


def bench_ssd():
    rows = []
    for (B, T, H, P, N, chunk) in [(2, 2048, 32, 64, 128, 128)]:
        x = jnp.asarray(RNG.normal(size=(B, T, H, P)), jnp.float32)
        dt = jnp.asarray(RNG.uniform(0.001, 0.1, (B, T, H)), jnp.float32)
        A = jnp.asarray(-RNG.uniform(0.5, 2.0, H), jnp.float32)
        Bm = jnp.asarray(RNG.normal(size=(B, T, N)), jnp.float32)
        Cm = jnp.asarray(RNG.normal(size=(B, T, N)), jnp.float32)
        fn = jax.jit(lambda *a: ssd_scan(*a, chunk=chunk, use_pallas=False))
        us = _time(fn, x, dt, A, Bm, Cm, iters=5)
        rows.append((f"ssd_scan/B{B}T{T}H{H}", us, f"chunk{chunk}"))
    return rows

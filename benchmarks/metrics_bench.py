"""Fleet-metrics-plane benchmark: parity gate + forced-breach incident.

Two arms, both CI-gated (the ``metrics-smoke`` job):

* **parity** — shifting_hotspot / full_adaptive, three drivers: metrics
  plane OFF (fused), ON (fused), ON (per-epoch).  Gates: the
  ``EpochMetrics`` stream is bit-identical with the ring on vs off (the
  plane is a pure observer), every ring leaf is bitwise equal between
  the fused scan and the per-epoch reference loop, and the fused step
  still compiles exactly once.
* **breach** — retry_storm on the *plain* (uncontrolled) arm with the
  overload queue physics on: admission stays open, so the storm drives
  the fleet p999 through the declared SLO bound.  Gates: the burn-rate
  alert's firing epochs match :func:`repro.telemetry.slo.reference_alerts`
  (an independent numpy oracle over the same f32 series) **exactly**;
  the rising edge triggered a flight-recorder dump; and
  ``incident.report()`` emits a complete postmortem (alert timeline,
  breach list, flight dump paths, p999 attribution shares, retry
  orbits, stage timers).  Artifacts: ``INCIDENT_metrics_smoke.{json,md}``,
  ``METRICS_view.json`` (the dashboard input), ``DASH_metrics.txt`` (the
  rendered terminal snapshot), plus the OpenMetrics exposition check.

Run: ``PYTHONPATH=src python -m benchmarks.metrics_bench
[--quick] [--json BENCH_metrics.json] [--no-check]``
"""

from __future__ import annotations

import argparse
import json
import sys
import time

SMOKE_TAG = "metrics_smoke"
VIEW_ARTIFACT = "METRICS_view.json"
DASH_ARTIFACT = "DASH_metrics.txt"


def scenario_config(quick: bool):
    from repro.cluster import ScenarioConfig

    if quick:
        return ScenarioConfig(n_epochs=16, epoch_ops=512, n_records=2048,
                              value_dim=4, seed=7)
    return ScenarioConfig(n_epochs=24, epoch_ops=2048, n_records=4096,
                          value_dim=8, seed=7)


def slo_spec(quick: bool):
    from repro.telemetry.slo import SLO

    # bound well under the storm's sustained tail so the breach is
    # forced, objective/windows tight enough that the burn alert fires
    # within the run
    return SLO(name="p999_fleet", series="p999",
               bound=120.0 if quick else 150.0,
               objective=0.9, fast_window=2, slow_window=4,
               fast_burn=2.0, slow_burn=1.0)


# ---------------------------------------------------------------------------
# arm 1: pure-observer parity
# ---------------------------------------------------------------------------

def run_parity(quick: bool) -> tuple[list[dict], list[str]]:
    import numpy as np

    from repro.cluster import (ClusterConfig, EpochDriver, make_policy,
                               make_scenario, summarize)
    from repro.telemetry.metrics import MetricsConfig

    scfg = scenario_config(quick)

    def ccfg(metrics):
        return ClusterConfig(num_nodes=8, num_ranges=32, replication=2,
                             r_max=4, n_clients=16, report_every=2,
                             imbalance_threshold=1.1, max_moves_per_round=6,
                             metrics=metrics)

    def drive(metrics, fused):
        scen = make_scenario("shifting_hotspot", scfg,
                             theta=1.2, shift_every=2)
        drv = EpochDriver(scen, make_policy("full_adaptive"),
                          ccfg(metrics), fused=fused)
        t0 = time.perf_counter()
        rows = drv.run()
        return drv, rows, time.perf_counter() - t0

    mcfg = MetricsConfig(window=64, topk=4)
    drv_off, rows_off, _ = drive(None, True)
    drv_on, rows_on, wall = drive(mcfg, True)
    drv_ref, rows_ref, _ = drive(mcfg, False)

    problems = []
    if [r.to_row() for r in rows_off] != [r.to_row() for r in rows_on]:
        problems.append(
            "parity: metrics-on EpochMetrics rows differ from metrics-off "
            "(the ring perturbed the stream it observes)")
    if [r.to_row() for r in rows_on] != [r.to_row() for r in rows_ref]:
        problems.append("parity: fused rows differ from per-epoch rows")
    if not np.array_equal(np.asarray(drv_on.metrics.ring),
                          np.asarray(drv_ref.metrics.ring)):
        problems.append(
            "parity: fused metrics ring != per-epoch ring (bitwise)")
    if int(drv_on.metrics.pos) != int(drv_ref.metrics.pos):
        problems.append("parity: ring pos diverged fused vs per-epoch")
    for tag, drv in (("off", drv_off), ("on", drv_on)):
        if drv.traces != 1:
            problems.append(
                f"parity: fused step (metrics {tag}) traced {drv.traces}x")

    row = summarize(rows_on)
    row.update(bench="metrics_parity", arm="parity", wall_s=round(wall, 3),
               traces=drv_on.traces, ring_pos=int(drv_on.metrics.pos),
               n_series=drv_on.met_layout.n_series)
    return [row], problems


# ---------------------------------------------------------------------------
# arm 2: forced SLO breach -> burn alert -> incident artifact
# ---------------------------------------------------------------------------

def run_breach(quick: bool, out_dir: str = "."
               ) -> tuple[list[dict], list[str]]:
    import numpy as np

    from repro.cluster import (ClusterConfig, EpochDriver, TelemetryConfig,
                               make_policy, make_scenario, summarize)
    from repro.overload import OverloadConfig
    from repro.telemetry import dashboard, incident
    from repro.telemetry import metrics as MTR
    from repro.telemetry import slo as SLOM
    from repro.telemetry.metrics import MetricsConfig

    spec = slo_spec(quick)
    ovl = (OverloadConfig(queue_cap=48, service_rate=80, inflation=3.0,
                          max_level=3, backoff_base=1, jitter_span=2,
                          queue_weight=2) if quick else
           OverloadConfig(queue_cap=192, service_rate=320, inflation=3.0,
                          max_level=3, backoff_base=1, jitter_span=2,
                          queue_weight=2))
    ccfg = ClusterConfig(
        num_nodes=10, num_ranges=20, replication=2, overload=ovl,
        standby_nodes=(8, 9), report_every=2,
        telemetry=TelemetryConfig(sample_rate=1 / 4 if quick else 1 / 64,
                                  flight_dir=out_dir, flight_epochs=4),
        metrics=MetricsConfig(window=64, slos=(spec,)),
    )
    scen = make_scenario("retry_storm", scenario_config(quick))
    drv = EpochDriver(scen, make_policy("full_adaptive"), ccfg, fused=True)
    t0 = time.perf_counter()
    rows = drv.run()
    wall = time.perf_counter() - t0

    problems = []
    # ground truth: the independent numpy oracle over the same f32 series
    vals = np.asarray([r.p999 for r in rows], np.float32)
    ref = SLOM.reference_alerts(vals, spec)
    fired = drv.met_engine.firing_epochs(spec.name)
    if not fired:
        problems.append("breach: the forced p999 SLO never fired")
    if fired != ref["fire_epochs"]:
        problems.append(
            f"breach: alert firing epochs {fired} != ground truth "
            f"{ref['fire_epochs']}")
    if not any(b.startswith("slo_burn:") for b in drv.telemetry.breaches):
        problems.append("breach: rising edge did not reach the recorder")
    if not drv.telemetry.flight.dumps:
        problems.append("breach: no flight-recorder dump was written")

    # one-command postmortem, checked for completeness
    doc = incident.report(drv, out_dir=out_dir, tag=SMOKE_TAG)
    for key in ("alerts", "slos", "metrics", "breaches", "flight_dumps",
                "p999_attribution", "stage_timers"):
        if not doc.get(key):
            problems.append(f"breach: incident report missing '{key}'")
    if "retry_orbits" not in doc:     # may legitimately be empty
        problems.append("breach: incident report missing 'retry_orbits'")
    if doc.get("alerts", {}).get("fires", 0) < 1:
        problems.append("breach: incident alert timeline has no fire")
    if "share" not in doc.get("p999_attribution", {}):
        problems.append("breach: attribution lacks bucket shares")

    # dashboard snapshot + OpenMetrics exposition over the same view
    view = drv.metrics_view()
    MTR.write_view(f"{out_dir}/{VIEW_ARTIFACT}", view,
                   alerts=drv.alert_timeline())
    with open(f"{out_dir}/{VIEW_ARTIFACT}") as f:
        snap = dashboard.render(json.load(f))
    with open(f"{out_dir}/{DASH_ARTIFACT}", "w") as f:
        f.write(snap)
    if "p999" not in snap or "fire" not in snap:
        problems.append("breach: dashboard snapshot lacks p999/alert rows")
    om = MTR.to_openmetrics(view)
    if "turbokv_p999" not in om or not om.endswith("# EOF\n"):
        problems.append("breach: OpenMetrics exposition malformed")

    row = summarize(rows)
    row.update(bench="metrics_breach", arm="breach", wall_s=round(wall, 3),
               traces=drv.traces, slo_bound=spec.bound,
               fire_epochs=fired, ref_fire_epochs=ref["fire_epochs"],
               alert_fires=doc["alerts"]["fires"],
               flight_dumps=len(drv.telemetry.flight.dumps),
               incident_paths=doc.get("paths", []))
    return [row], problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=None, help="write rows to this path")
    ap.add_argument("--out-dir", default=".")
    ap.add_argument("--no-check", action="store_true")
    args = ap.parse_args(argv)

    rows_p, prob_p = run_parity(args.quick)
    rows_b, prob_b = run_breach(args.quick, args.out_dir)
    rows = rows_p + rows_b
    problems = prob_p + prob_b
    for r in rows:
        print(f"{r['bench']:16s} wall {r['wall_s']:7.2f}s "
              f"traces {r['traces']}")

    doc = {"quick": args.quick, "parity_ok": not prob_p,
           "alert_epoch_ok": not any("firing" in p or "never fired" in p
                                     for p in prob_b),
           "incident_complete": not any("incident" in p for p in prob_b),
           "rows": rows}
    if args.json:
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=1, default=str)
        print(f"wrote {args.json} ({len(rows)} rows)")
        from benchmarks import history
        history.append("metrics", doc)

    if not args.no_check and problems:
        print("\nGATE FAILURES:", file=sys.stderr)
        for p in problems:
            print(f"  - {p}", file=sys.stderr)
        return 1
    print("metrics gates: OK" if not problems else
          f"metrics gates: {len(problems)} problem(s) (unchecked)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

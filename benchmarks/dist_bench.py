"""Scale-out benchmark: the dist-backend period pipeline on 8 devices (PR 8).

Four drivers run the same shifting-hotspot scenario under the same
adaptive policy and report steady-state epochs/s, host syncs/epoch and
per-stage wall breakdowns:

* ``single_host``       — the oracle-backend pipeline at the repo's
  default control cadence (period=1): the production single-host path;
* ``single_host_fused`` — the same pipeline with the whole run fused
  into one control period: the single-host roofline;
* ``dist_epoch``        — the dist backend stepping shard_map once per
  epoch (the pre-PR-8 dist path), whole-run period;
* ``dist_fused``        — whole periods as ONE shard_map program with
  the epoch scan inside (PR 8's tentpole), whole-run period.

Because jax pins the host device count at first init, the measurement
runs in a subprocess with ``--xla_force_host_platform_device_count=8``.
Those devices are host threads: on a c-core box the 8 program instances
serialize ~8/c-fold, so the roofline ratio is environment-bound, not
program-bound (measured mesh-size scaling on one core: 80.5 / 52.1 /
27.5 / 21.9 epochs/s at 1 / 2 / 4 / 8 devices — near-linear in the
serialized instance count, i.e. the fused program itself adds almost
nothing over the oracle at mesh size 1).

Gates (skipped with ``--no-check``):

* **parity** — ``dist_fused`` must be bit-identical to ``dist_epoch``:
  the full :class:`EpochMetrics` stream and the final store
  (keys/values/overflow);
* **ratio**  — ``single_host`` steady-state epochs/s may beat
  ``dist_fused`` by at most ``RATIO_GATE`` (2x), i.e. scale-out keeps
  >= 0.5x the production single-host throughput even where the host
  serializes all 8 devices (on real parallel devices the ratio drops
  toward the collective cost alone);
* **syncs**  — ``dist_fused`` host syncs/epoch must not exceed
  ``dist_epoch``'s.

Run: ``PYTHONPATH=src python -m benchmarks.dist_bench
[--quick] [--json BENCH_dist.json] [--no-check]``
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import subprocess
import sys
import time

SCENARIO = "shifting_hotspot"
POLICY = "full_adaptive"
RATIO_GATE = 2.0


def _stage_breakdown(drv) -> dict:
    s = drv.telemetry.timers.summary()
    return {"stage_s": s["stage_s"], "stage_share": s["stage_share"]}


def worker(quick: bool) -> int:
    """Forced-8-device measurement (subprocess body)."""
    import jax
    import numpy as np

    from benchmarks.balance_bench import (
        _steady_epochs_per_s, cluster_config, scenario_config,
        scenario_kwargs,
    )
    from repro.cluster import (EpochDriver, make_policy, make_scenario,
                               summarize)
    from repro.core import DistConfig
    from repro.telemetry import TelemetryConfig

    mesh = jax.make_mesh((8,), ("data",))
    scfg = scenario_config(quick)
    kw = scenario_kwargs(SCENARIO, scfg)
    # dist rows fuse the whole run into one control period (the
    # run_profile framing) so the one-shard_map-per-period structure
    # actually amortizes; the bucket bound matches balance_bench's
    # switch-queue pressure column (overflow drops count as retries)
    period = scfg.n_epochs
    dist_cfg = DistConfig(bucket_cap=16 if quick else 24)
    variants = (
        ("single_host", "oracle", True, 1),
        ("single_host_fused", "oracle", True, period),
        ("dist_epoch", "dist", False, period),
        ("dist_fused", "dist", True, period),
    )
    rows, finals = [], {}
    for name, backend, fused, per in variants:
        scen = make_scenario(SCENARIO, scfg, **kw)
        drv = EpochDriver(scen, make_policy(POLICY),
                          cluster_config(quick, period=per),
                          backend=backend,
                          mesh=mesh if backend == "dist" else None,
                          dist_cfg=dist_cfg if backend == "dist" else None,
                          fused=fused)
        t0 = time.perf_counter()
        epochs = drv.run()
        wall = time.perf_counter() - t0
        syncs_run = drv.host_syncs  # before the steady re-runs accumulate
        steady = _steady_epochs_per_s(drv, scfg.n_epochs, repeats=3)
        finals[name] = (drv, epochs)

        # separate profiled pass so the timed runs carry no telemetry
        scen_p = make_scenario(SCENARIO, scfg, **kw)
        ccfg_p = dataclasses.replace(
            cluster_config(quick, period=per),
            telemetry=TelemetryConfig(sample_rate=1.0 / 64.0))
        drv_p = EpochDriver(scen_p, make_policy(POLICY), ccfg_p,
                            backend=backend,
                            mesh=mesh if backend == "dist" else None,
                            dist_cfg=dist_cfg if backend == "dist" else None,
                            fused=fused)
        drv_p.run()

        row = summarize(epochs)
        row.update({
            "bench": "dist_scaleout",
            "variant": name,
            "backend": backend,
            "fused": fused,
            "period": per,
            "epochs": scfg.n_epochs,
            "wall_s": round(wall, 3),
            "steady_eps": round(steady, 2),
            "host_syncs": syncs_run,
            "host_syncs_per_epoch": round(syncs_run / scfg.n_epochs, 2),
            "traces": drv.traces,
            **_stage_breakdown(drv_p),
        })
        rows.append(row)

    # bit parity: fused dist vs per-epoch dist
    problems = []
    (drv_r, ep_r), (drv_f, ep_f) = finals["dist_epoch"], finals["dist_fused"]
    for a, b in zip(ep_r, ep_f):
        da, db = dataclasses.asdict(a), dataclasses.asdict(b)
        for k in da:
            if da[k] != db[k]:
                problems.append(
                    f"parity: epoch {a.epoch} field {k}: {da[k]} != {db[k]}")
    for f in ("keys", "values", "overflow"):
        if not np.array_equal(np.asarray(getattr(drv_r.store, f)),
                              np.asarray(getattr(drv_f.store, f))):
            problems.append(f"parity: final store field {f} differs")
    if drv_f.traces != 1:
        problems.append(f"dist_fused retraced: {drv_f.traces} != 1")

    print(json.dumps({"rows": rows, "problems": problems}))
    return 0


def check(rows: list[dict]) -> list[str]:
    by = {r["variant"]: r for r in rows if r.get("bench") == "dist_scaleout"}
    problems = []
    ratio = by["single_host"]["steady_eps"] / max(
        by["dist_fused"]["steady_eps"], 1e-9)
    roofline = by["single_host_fused"]["steady_eps"] / max(
        by["dist_fused"]["steady_eps"], 1e-9)
    print(f"ratio vs single_host {ratio:.2f}x (gate {RATIO_GATE}x); "
          f"vs fused roofline {roofline:.2f}x (informational — "
          f"host-serialized mesh)")
    if ratio > RATIO_GATE:
        problems.append(
            f"ratio: single-host is {ratio:.2f}x dist_fused steady epochs/s "
            f"(gate {RATIO_GATE}x)")
    if (by["dist_fused"]["host_syncs_per_epoch"]
            > by["dist_epoch"]["host_syncs_per_epoch"]):
        problems.append(
            f"syncs: dist_fused {by['dist_fused']['host_syncs_per_epoch']}"
            f"/epoch > dist_epoch "
            f"{by['dist_epoch']['host_syncs_per_epoch']}/epoch")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="CI smoke sizes")
    ap.add_argument("--json", default=None, help="write rows to this path")
    ap.add_argument("--no-check", action="store_true",
                    help="skip the gates (exploratory runs)")
    ap.add_argument("--worker", action="store_true",
                    help=argparse.SUPPRESS)  # internal: the forked mesh run
    args = ap.parse_args(argv)

    if args.worker:
        return worker(args.quick)

    env = {
        **os.environ,
        "XLA_FLAGS": "--xla_force_host_platform_device_count=8 "
        + os.environ.get("XLA_FLAGS", ""),
        "JAX_PLATFORMS": "cpu",
    }
    cmd = [sys.executable, "-m", "benchmarks.dist_bench", "--worker"]
    if args.quick:
        cmd.append("--quick")
    r = subprocess.run(cmd, env=env, capture_output=True, text=True)
    if r.returncode != 0:
        print(r.stdout)
        print(r.stderr, file=sys.stderr)
        raise RuntimeError("dist_bench worker failed")
    payload = json.loads(r.stdout.splitlines()[-1])
    rows, problems = payload["rows"], payload["problems"]

    for row in rows:
        shares = ", ".join(f"{k} {v:.0%}"
                           for k, v in sorted(row["stage_share"].items(),
                                              key=lambda kv: -kv[1]))
        print(f"{row['variant']:12s} steady {row['steady_eps']:8.2f} ep/s "
              f"wall {row['wall_s']:6.2f}s "
              f"syncs/epoch {row['host_syncs_per_epoch']:5.2f} "
              f"traces {row['traces']}  [{shares}]")

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"quick": args.quick, "rows": rows}, f, indent=1)
        print(f"wrote {args.json} ({len(rows)} rows)")
        from benchmarks import history
        history.append("dist", {"quick": args.quick, "rows": rows})

    if not args.no_check:
        problems = problems + check(rows)
        if problems:
            print("ACCEPTANCE FAILED:")
            for p in problems:
                print(" -", p)
            return 1
        print("acceptance: dist_fused bit-identical to dist_epoch; "
              f"single-host <= {RATIO_GATE}x dist_fused steady epochs/s; "
              "fused syncs/epoch <= per-epoch")
    return 0


if __name__ == "__main__":
    sys.exit(main())

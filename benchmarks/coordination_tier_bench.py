"""Coordination-tier benchmark: staleness windows, redirects, survival.

Runs the switch-replicated directory tier (``repro.coordination_tier``)
through three columns:

* **staleness sweep** — shifting_hotspot under ``full_adaptive`` with the
  per-hop install lag swept over ``SWEEP_LAGS``: a longer switch chain
  delay widens the window in which ingress copies disagree with the
  quorum commit, so the versioned-redirect share (``redirected /
  routed``) must grow with the lag while the zero-lag point stays
  redirect-free.  ``mean_p999`` rides along as the priced cost of the
  extra redirect hop.
* **parity arm** — the zero-lag tier vs ``coordination=None``: every
  non-coordination field of the ``EpochMetrics`` stream must be
  bit-identical (the tier is an accounting plane; with no staleness it
  must not perturb what it prices).
* **fault arms** — ``lease_expiry`` (staging stalls until failover moves
  leadership down the chain) and ``split_brain`` (a rogue switch installs
  a rotated-ownership table), each under the quorum arm
  (``CoordConfig(quorum=True)``) and the trusting baseline
  (``quorum=False``).

**Coordination gate** (CI-enforced):

* every row conserves exactly: ``routed == direct + redirected`` per
  epoch, and ``routed`` equals the epoch batch;
* sweep: zero lag -> zero redirects and zero mis-serves; the redirect
  share is positive at lag 1 and does not shrink at the largest lag;
* parity: zero-lag rows == tier-off rows on all non-coordination fields;
* faults: the quorum arm serves **zero** queries off a wrong owner and
  pays for it only in redirects (> 0 on both stressors); the baseline
  arm measurably mis-serves (> 0) and never redirects; lease expiry
  actually fails over (leadership moved down the chain);
* every run's device step compiled exactly once.

Run: ``PYTHONPATH=src python -m benchmarks.coordination_tier_bench
[--quick] [--json BENCH_coord_tier.json] [--no-check]``
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time

SWEEP_LAGS = (0, 1, 2, 4)
SWEEP_SCENARIO = "shifting_hotspot"
FAULT_SCENARIOS = ("lease_expiry", "split_brain")

# the coordination observables + control notes (stripped for the parity arm)
COORD_ROW_KEYS = ("routed", "direct", "redirected", "mis_served",
                  "stale_switches", "coordination")


def scenario_config(quick: bool):
    from repro.cluster import ScenarioConfig

    if quick:
        return ScenarioConfig(n_epochs=12, epoch_ops=512, n_records=2048,
                              value_dim=4, seed=7)
    return ScenarioConfig(n_epochs=20, epoch_ops=1024, n_records=4096,
                          value_dim=4, seed=7)


def cluster_config(quick: bool, coord):
    from repro.cluster import ClusterConfig

    return ClusterConfig(num_nodes=8, num_ranges=32 if quick else 64,
                         replication=2, r_max=4, n_clients=32,
                         report_every=2, imbalance_threshold=1.1,
                         max_moves_per_round=8, coordination=coord)


def _scen_kw(name: str) -> dict:
    if name == SWEEP_SCENARIO:
        return dict(theta=1.2, shift_every=2)
    if name == "lease_expiry":
        return dict(theta=1.2, shift_every=2, expire_epoch=3)
    if name == "split_brain":
        return dict(theta=1.2, shift_every=2, split_epoch=3, heal_epoch=8,
                    switch=1)
    raise ValueError(name)


def _drive(name: str, quick: bool, coord, policy_name="full_adaptive"):
    from repro.cluster import EpochDriver, make_policy, make_scenario

    scen = make_scenario(name, scenario_config(quick), **_scen_kw(name))
    drv = EpochDriver(scen, make_policy(policy_name),
                      cluster_config(quick, coord))
    t0 = time.perf_counter()
    epochs = drv.run()
    wall = time.perf_counter() - t0
    return drv, epochs, wall


def _row(drv, epochs, wall, **extra) -> dict:
    from repro.cluster import summarize

    row = summarize(epochs)
    row["wall_s"] = round(wall, 3)
    row["traces"] = drv.traces
    row["conservation_ok"] = all(
        r.routed == r.direct + r.redirected for r in epochs
    )
    row["batch_routed_ok"] = all(
        r.routed == drv.scenario.cfg.epoch_ops for r in epochs
    )
    if row["total_routed"] > 0:
        row["redirect_share"] = row["total_redirected"] / row["total_routed"]
    else:
        row["redirect_share"] = 0.0
    if drv.coord_mgr is not None:
        row.update({f"mgr_{k}": v
                    for k, v in drv.coord_mgr.summary().items()})
    row.update(extra)
    return row


def run_sweep(quick: bool, verbose: bool = True) -> list[dict]:
    from repro import coordination_tier as CT

    rows = []
    for lag in SWEEP_LAGS:
        coord = CT.CoordConfig(n_switches=4, lag_per_hop=lag, quorum=True)
        drv, epochs, wall = _drive(SWEEP_SCENARIO, quick, coord)
        row = _row(drv, epochs, wall, bench="coord_sweep", lag=lag,
                   quorum=True)
        rows.append(row)
        if verbose:
            print(
                f"[coord-sweep] {SWEEP_SCENARIO:17s} lag {lag} "
                f"redirects {row['total_redirected']:5d} "
                f"share {row['redirect_share']:.4f} "
                f"mis {row['total_mis_served']:4d} "
                f"stale_sw<= {row['max_stale_switches']} "
                f"p999 {row['mean_p999']:7.1f} traces {row['traces']}"
            )
    return rows


def run_parity(quick: bool, verbose: bool = True) -> list[dict]:
    """Tier-off vs zero-lag tier: the accounting-plane bit-parity arm."""
    from repro import coordination_tier as CT

    _, e_off, _ = _drive(SWEEP_SCENARIO, quick, None)
    drv_on, e_on, wall = _drive(
        SWEEP_SCENARIO, quick,
        CT.CoordConfig(n_switches=4, lag_per_hop=0, quorum=True))

    def strip(r):
        d = dataclasses.asdict(r)
        d = {k: v for k, v in d.items() if k not in COORD_ROW_KEYS}
        d["events"] = [e for e in d["events"] if not e.startswith("coord_")]
        return d

    mismatch = sum(strip(a) != strip(b) for a, b in zip(e_off, e_on))
    row = _row(drv_on, e_on, wall, bench="coord_parity", lag=0, quorum=True,
               parity_epochs=len(e_on),
               parity_mismatches=mismatch + abs(len(e_off) - len(e_on)))
    if verbose:
        print(
            f"[coord-parity] zero-lag vs tier-off: "
            f"{row['parity_epochs']} epochs, "
            f"{row['parity_mismatches']} mismatched "
            f"(redirects {row['total_redirected']}, traces {row['traces']})"
        )
    return [row]


def run_faults(quick: bool, verbose: bool = True) -> list[dict]:
    from repro import coordination_tier as CT

    rows = []
    for sname in FAULT_SCENARIOS:
        for arm, quorum in (("quorum", True), ("baseline", False)):
            coord = CT.CoordConfig(n_switches=4, lag_per_hop=1,
                                   quorum=quorum)
            drv, epochs, wall = _drive(sname, quick, coord)
            row = _row(drv, epochs, wall, bench="coord_fault",
                       arm=arm, lag=1, quorum=quorum)
            rows.append(row)
            if verbose:
                print(
                    f"[coord-fault] {sname:13s} {arm:8s} "
                    f"mis {row['total_mis_served']:5d} "
                    f"redirects {row['total_redirected']:5d} "
                    f"failovers {row['mgr_failovers']} "
                    f"stalls {row['mgr_stall_pulls']} "
                    f"traces {row['traces']}"
                )
    return rows


def check_coordination(rows: list[dict]) -> list[str]:
    """The coordination gate (see module docstring)."""
    problems: list[str] = []

    for r in rows:
        tag = f"{r.get('bench')}/{r.get('scenario')}/{r.get('arm', r.get('lag'))}"
        if not r.get("conservation_ok", False):
            problems.append(f"{tag}: routed != direct + redirected on "
                            "some epoch (conservation broke)")
        if not r.get("batch_routed_ok", False):
            problems.append(f"{tag}: routed != epoch batch on some epoch")
        if r.get("traces") != 1:
            problems.append(f"{tag}: step traced {r.get('traces')}x "
                            "(expected 1)")

    sweep = {r["lag"]: r for r in rows if r.get("bench") == "coord_sweep"}
    z = sweep.get(0)
    if z and (z["total_redirected"] != 0 or z["total_mis_served"] != 0):
        problems.append(
            f"coord_sweep: zero-lag tier redirected "
            f"{z['total_redirected']} / mis-served {z['total_mis_served']} "
            "(must both be 0)")
    if 1 in sweep and sweep[1]["total_redirected"] <= 0:
        problems.append("coord_sweep: lag 1 produced no redirects — the "
                        "staleness window never opened")
    lags = sorted(sweep)
    if len(lags) >= 2:
        lo, hi = sweep[lags[1]], sweep[lags[-1]]
        if hi["redirect_share"] < lo["redirect_share"]:
            problems.append(
                f"coord_sweep: redirect share shrank with lag "
                f"({lags[-1]}: {hi['redirect_share']:.4f} < "
                f"{lags[1]}: {lo['redirect_share']:.4f})")
    for r in sweep.values():
        if r["total_mis_served"] != 0:
            problems.append(
                f"coord_sweep: lag {r['lag']} mis-served "
                f"{r['total_mis_served']} under quorum reads (must be 0)")

    for r in rows:
        if r.get("bench") != "coord_parity":
            continue
        if r.get("parity_mismatches", 1) != 0:
            problems.append(
                f"coord_parity: {r['parity_mismatches']} epoch rows "
                "diverge between zero-lag tier and coordination=None")
        if r["total_redirected"] != 0:
            problems.append("coord_parity: zero-lag arm redirected "
                            f"{r['total_redirected']} queries")

    faults = {(r["scenario"], r["arm"]): r for r in rows
              if r.get("bench") == "coord_fault"}
    for sname in FAULT_SCENARIOS:
        q = faults.get((sname, "quorum"))
        b = faults.get((sname, "baseline"))
        if q is None or b is None:
            problems.append(f"coord_fault: missing an arm for {sname}")
            continue
        if q["total_mis_served"] != 0:
            problems.append(
                f"coord_fault: {sname}/quorum mis-served "
                f"{q['total_mis_served']} queries (must be 0)")
        if q["total_redirected"] <= 0:
            problems.append(
                f"coord_fault: {sname}/quorum never redirected — the "
                "fault opened no stale window")
        if b["total_mis_served"] <= 0:
            problems.append(
                f"coord_fault: {sname}/baseline never mis-served — the "
                "stressor is not stressing")
        if b["total_redirected"] != 0:
            problems.append(
                f"coord_fault: {sname}/baseline redirected "
                f"{b['total_redirected']} (the trusting arm must not)")
        if sname == "lease_expiry" and q["mgr_failovers"] < 1:
            problems.append("coord_fault: lease_expiry/quorum never "
                            "failed leadership over")
    return problems


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke sizes (12 epochs x 512 ops)")
    ap.add_argument("--json", default=None, help="write rows to this path")
    ap.add_argument("--no-check", action="store_true",
                    help="skip the coordination gate (exploratory runs)")
    args = ap.parse_args(argv)

    rows = run_sweep(args.quick)
    rows += run_parity(args.quick)
    rows += run_faults(args.quick)

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"quick": args.quick, "rows": rows}, f, indent=1)
        print(f"wrote {args.json} ({len(rows)} rows)")
        from benchmarks import history
        history.append("coord_tier", {"quick": args.quick, "rows": rows})

    if not args.no_check:
        problems = check_coordination(rows)
        if problems:
            print("COORDINATION GATE FAILED:")
            for p in problems:
                print("  -", p)
            return 1
        print("coordination gate: conservation exact on every row; zero "
              "lag is redirect-free and bit-identical to the tier-less "
              "stream; redirect share grows with the staleness window; "
              "the quorum arm served zero queries wrong under lease "
              "expiry and split brain while the trusting baseline "
              "measurably mis-served; one compiled step per run")
    return 0


if __name__ == "__main__":
    sys.exit(main())

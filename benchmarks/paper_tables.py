"""Reproductions of the paper's tables/figures (BMV2 testbed -> JAX sim).

Setup mirrors §8: 16 storage nodes, 128-record index table, chain length 3,
range partitioning, YCSB workloads (16-byte keys -> uint32 matching values,
128-byte values -> 32 f32 words).  Absolute times are abstract ticks (the
paper's milliseconds are a Mininet artifact); the reproduced quantities are
the *ratios* between coordination models.

Timing runs through the vectorized DES engine (``repro.core.des``) by
default: every figure builds its full (workload × coordination-mode)
scenario set, stacks the hop plans along a leading scenario axis, and
simulates the whole sweep in **one** engine call.  ``engine="reference"``
replays the same scenarios one by one through the heapq oracle — the
results are bit-identical, only slower.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import core as C
from repro.data.ycsb import WorkloadConfig, load_phase, run_phase

N_NODES = 16
N_RANGES = 128
REPLICATION = 3
N_CLIENTS = 4  # the paper's testbed: 4 client hosts replaying YCSB streams


@dataclasses.dataclass
class BenchResult:
    mode: str
    throughput: float          # ops / tick
    read_mean: float
    read_p50: float
    read_p99: float
    write_mean: float
    write_p50: float
    write_p99: float
    scan_mean: float
    scan_p50: float
    scan_p99: float


def _percentiles(lat, mask):
    lat = np.asarray(lat)[np.asarray(mask)]
    if lat.size == 0:
        return (float("nan"),) * 3
    return float(lat.mean()), float(np.percentile(lat, 50)), float(np.percentile(lat, 99))


# ---------------------------------------------------------------------------
# scenario construction + fused simulation
# ---------------------------------------------------------------------------


def build_scenarios(workloads, *, seed: int = 0, run_store_ops: bool = False,
                    modes=C.MODES):
    """Route every workload and expand it into one scenario per mode.

    Returns (scenarios, plans): ``scenarios[i] = (label, mode, opcodes,
    wcfg)`` describes ``plans[i]`` (a (B, H) HopPlan).  All workloads must
    share ``n_ops`` so the plans can be stacked and fused.
    """
    scenarios, plans = [], []
    for label, wcfg in workloads:
        d = C.make_directory(N_RANGES, N_NODES, REPLICATION)
        opcodes, keys, end_keys, values, arrivals = run_phase(wcfg)
        q = C.make_queries(jnp.asarray(keys), jnp.asarray(opcodes),
                           jnp.asarray(values), jnp.asarray(end_keys))
        dec, d = C.route(d, q)
        if run_store_ops:  # functional execution (correctness-coupled timing)
            store = C.make_store(N_NODES, capacity=wcfg.n_records,
                                 value_dim=wcfg.value_dim)
            lk, lv = load_phase(wcfg)
            ql = C.make_queries(jnp.asarray(lk), jnp.full((len(lk),), C.OP_PUT),
                                jnp.asarray(lv))
            dl, d = C.route(d, ql)
            store, _ = C.apply_routed(store, ql, dl)
            store, _ = C.apply_routed(store, q, dec)
        for mode in modes:
            plans.append(C.plan_hops(q, dec, mode, C.LatencyModel(),
                                     rng=jax.random.PRNGKey(seed),
                                     num_nodes=N_NODES))
            scenarios.append((label, mode, opcodes, wcfg))
    return scenarios, plans


def simulate_scenarios(plans, *, engine: str = "vectorized",
                       n_clients: int = N_CLIENTS):
    """Closed-loop simulate a scenario list -> (latencies, makespans).

    ``vectorized``: one fused engine call over the stacked plans.
    ``reference``: the heapq oracle, one scenario at a time (bit-identical).
    """
    if engine == "reference":
        lats, mks = [], []
        for p in plans:
            lat, mk = C.simulate_closed_loop_reference(
                p, n_clients=n_clients, num_nodes=N_NODES)
            lats.append(np.asarray(lat))
            mks.append(float(mk))
        return lats, mks
    if engine != "vectorized":
        raise ValueError(f"engine must be 'reference' or 'vectorized', got {engine!r}")
    lat, mk = C.simulate_closed_loop(C.stack_plans(plans),
                                     n_clients=n_clients, num_nodes=N_NODES)
    return list(np.asarray(lat)), [float(x) for x in np.asarray(mk)]


def _to_result(mode, wcfg, opcodes, lat, makespan) -> BenchResult:
    is_read = opcodes == C.OP_GET
    is_write = opcodes == C.OP_PUT
    is_scan = opcodes == C.OP_SCAN
    rm, r50, r99 = _percentiles(lat, is_read)
    wm, w50, w99 = _percentiles(lat, is_write)
    sm, s50, s99 = _percentiles(lat, is_scan)
    return BenchResult(mode, wcfg.n_ops / max(makespan, 1e-9),
                       rm, r50, r99, wm, w50, w99, sm, s50, s99)


def run_workload(wcfg: WorkloadConfig, mode: str, *, seed: int = 0,
                 run_store_ops: bool = False,
                 engine: str = "vectorized") -> BenchResult:
    """Route + (optionally) execute a YCSB stream, then simulate one mode."""
    if mode not in C.MODES:
        raise ValueError(f"mode must be one of {C.MODES}")
    scenarios, plans = build_scenarios([("", wcfg)], seed=seed,
                                       run_store_ops=run_store_ops,
                                       modes=(mode,))
    lats, mks = simulate_scenarios(plans, engine=engine)
    return _to_result(mode, wcfg, scenarios[0][2], lats[0], mks[0])


# ---------------------------------------------------------------------------
# workload grids — shared with benchmarks/coordination_bench.py so the
# engine benchmark measures exactly the scenario set the figures use
# ---------------------------------------------------------------------------


def fig13a_workloads(n_ops: int):
    workloads = []
    for dist, theta in [("uniform", 0.0), ("zipf", 0.9), ("zipf", 0.95),
                        ("zipf", 0.99), ("zipf", 1.2)]:
        label = "uniform" if dist == "uniform" else f"zipf-{theta}"
        workloads.append((label, WorkloadConfig(
            distribution=dist, zipf_theta=theta, n_ops=n_ops,
            read_ratio=1.0, update_ratio=0.0)))
    return workloads


def fig13bc_workloads(n_ops: int):
    workloads = []
    for dist, theta in [("uniform", 0.0), ("zipf", 0.95)]:
        for wr in (0.0, 0.1, 0.3, 0.5, 0.7, 0.9):
            label = "uniform" if dist == "uniform" else f"zipf-{theta}"
            workloads.append(((label, wr), WorkloadConfig(
                distribution=dist, zipf_theta=theta, n_ops=n_ops,
                read_ratio=1 - wr, update_ratio=wr)))
    return workloads


def tables12_workloads(n_ops: int):
    return [(name, WorkloadConfig(
        distribution=dist, zipf_theta=theta, n_ops=n_ops,
        read_ratio=0.45, update_ratio=0.45, scan_ratio=0.10))
        for dist, theta, name in [("uniform", 0.0, "uniform"),
                                  ("zipf", 1.2, "zipf-1.2")]]


# ---------------------------------------------------------------------------
# Figure 13(a): throughput vs skewness, read-only
# ---------------------------------------------------------------------------


def fig13a_throughput_vs_skew(n_ops: int = 8192, engine: str = "vectorized"):
    scenarios, plans = build_scenarios(fig13a_workloads(n_ops))
    _, mks = simulate_scenarios(plans, engine=engine)
    return [(label, mode, wcfg.n_ops / max(mk, 1e-9))
            for (label, mode, _, wcfg), mk in zip(scenarios, mks)]


# ---------------------------------------------------------------------------
# Figure 13(b,c): throughput vs write ratio (uniform / zipf-0.95)
# ---------------------------------------------------------------------------


def fig13bc_throughput_vs_write_ratio(n_ops: int = 8192,
                                      engine: str = "vectorized"):
    scenarios, plans = build_scenarios(fig13bc_workloads(n_ops))
    _, mks = simulate_scenarios(plans, engine=engine)
    return [(label_wr[0], label_wr[1], mode, wcfg.n_ops / max(mk, 1e-9))
            for (label_wr, mode, _, wcfg), mk in zip(scenarios, mks)]


# ---------------------------------------------------------------------------
# Tables 1 & 2: latency analysis (uniform / zipf-1.2), mixed ops incl. scans
# ---------------------------------------------------------------------------


def tables12_latency(n_ops: int = 8192, engine: str = "vectorized"):
    scenarios, plans = build_scenarios(tables12_workloads(n_ops))
    lats, mks = simulate_scenarios(plans, engine=engine)
    out: dict[str, dict[str, BenchResult]] = {}
    for (name, mode, opcodes, wcfg), lat, mk in zip(scenarios, lats, mks):
        out.setdefault(name, {})[mode] = _to_result(mode, wcfg, opcodes, lat, mk)
    return out


# ---------------------------------------------------------------------------
# §5.1: load-balancing migration effect under skew
# ---------------------------------------------------------------------------


def load_balance_effect(n_ops: int = 8192, theta: float = 1.2):
    d = C.make_directory(N_RANGES, N_NODES, REPLICATION)
    wcfg = WorkloadConfig(distribution="zipf", zipf_theta=theta, n_ops=n_ops,
                          read_ratio=0.9, update_ratio=0.1)
    opcodes, keys, end_keys, values, arrivals = run_phase(wcfg)
    q = C.make_queries(jnp.asarray(keys), jnp.asarray(opcodes),
                       jnp.asarray(values), jnp.asarray(end_keys))

    # period 1: observe load
    dec, d = C.route(d, q)
    report, d = C.pull_report(d, 0)
    before = report.node_load
    imb_before = before.max() / max(before.mean(), 1e-9)

    # controller balances; same workload again (stationary popularity)
    ctl = C.Controller(d, C.ControllerConfig(imbalance_threshold=1.1,
                                             max_moves_per_round=16))
    ops = ctl.balance(report)
    d = ctl.directory()
    dec2, d = C.route(d, q)
    report2, d = C.pull_report(d, 1)
    after = report2.node_load
    imb_after = after.max() / max(after.mean(), 1e-9)
    return {
        "imbalance_before": float(imb_before),
        "imbalance_after": float(imb_after),
        "migrations": len(ops),
        "max_load_before": float(before.max()),
        "max_load_after": float(after.max()),
    }


# ---------------------------------------------------------------------------
# §6: hierarchical (multi-rack) routing — pod-crossing fraction
# ---------------------------------------------------------------------------


def hierarchy_stats(n_ops: int = 8192, n_pods: int = 2):
    d = C.make_directory(N_RANGES, N_NODES, REPLICATION, num_pods=n_pods)
    table = C.derive_pod_table(d, n_pods)
    wcfg = WorkloadConfig(n_ops=n_ops, read_ratio=0.5, update_ratio=0.5)
    opcodes, keys, end_keys, values, arrivals = run_phase(wcfg)
    q = C.make_queries(jnp.asarray(keys), jnp.asarray(opcodes), jnp.asarray(values))
    pods = np.asarray(C.route_pod(table, d, q))
    # clients uniformly spread over pods: crossing = target pod != client pod
    rng = np.random.default_rng(0)
    client_pod = rng.integers(0, n_pods, size=len(pods))
    crossing = float((pods != client_pod).mean())
    dec, d = C.route(d, q)
    # every routed target agrees with the pod-level direction (consistency)
    node_pods = np.asarray(d.node_addr[:, 0])
    agree = float((node_pods[np.asarray(dec.target)] == pods).mean())
    return {"pod_crossing_fraction": crossing, "pod_table_agreement": agree}

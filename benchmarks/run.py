"""Benchmark harness entry point — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  For the coordination-model
benchmarks us_per_call is the simulated mean latency per op (abstract ticks;
see benchmarks/paper_tables.py) and ``derived`` carries the reproduced
quantity (throughput / latency ratios vs server-driven coordination).  Run:

  PYTHONPATH=src python -m benchmarks.run [--quick] [--engine {reference,vectorized}]
                                          [--n-ops N] [--json BENCH_coordination.json]

``--engine`` selects the DES implementation (the vectorized engine is the
default; ``reference`` replays the heapq oracle).  ``--json`` additionally
writes every row plus engine wall-clock timings to a machine-readable file
so future changes have a perf trajectory to compare against.
"""

from __future__ import annotations

import argparse
import json
import time

from repro import core as C

_ROWS: list[tuple[str, float, str]] = []


def _emit(name: str, us: float, derived: str):
    _ROWS.append((name, us, derived))
    print(f"{name},{us:.2f},{derived}", flush=True)


def table_fig13a(n_ops: int, engine: str):
    from benchmarks.paper_tables import fig13a_throughput_vs_skew

    rows = fig13a_throughput_vs_skew(n_ops, engine=engine)
    base = {}
    for label, mode, thr in rows:
        base.setdefault(label, {})[mode] = thr
    for label, mode, thr in rows:
        rel = thr / base[label][C.SERVER_DRIVEN]
        _emit(f"fig13a/{label}/{mode}", 1e3 / max(thr, 1e-9),
              f"throughput={thr:.3f}ops_tick;vs_server={rel:.3f}x")


def table_fig13bc(n_ops: int, engine: str):
    from benchmarks.paper_tables import fig13bc_throughput_vs_write_ratio

    rows = fig13bc_throughput_vs_write_ratio(n_ops, engine=engine)
    base = {}
    for label, wr, mode, thr in rows:
        base.setdefault((label, wr), {})[mode] = thr
    for label, wr, mode, thr in rows:
        rel = thr / base[(label, wr)][C.SERVER_DRIVEN]
        _emit(f"fig13bc/{label}/wr{wr}/{mode}", 1e3 / max(thr, 1e-9),
              f"throughput={thr:.3f};vs_server={rel:.3f}x")


def tables_1_2(n_ops: int, engine: str):
    from benchmarks.paper_tables import tables12_latency

    out = tables12_latency(n_ops, engine=engine)
    for dist, modes in out.items():
        sv = modes[C.SERVER_DRIVEN]
        for mode, r in modes.items():
            _emit(
                f"table12/{dist}/{mode}/read", r.read_mean,
                f"p50={r.read_p50:.1f};p99={r.read_p99:.1f};vs_server_mean={r.read_mean / sv.read_mean:.3f}",
            )
            _emit(
                f"table12/{dist}/{mode}/write", r.write_mean,
                f"p50={r.write_p50:.1f};p99={r.write_p99:.1f};vs_server_mean={r.write_mean / sv.write_mean:.3f}",
            )
            _emit(
                f"table12/{dist}/{mode}/scan", r.scan_mean,
                f"p50={r.scan_p50:.1f};p99={r.scan_p99:.1f};vs_server_mean={r.scan_mean / sv.scan_mean:.3f}",
            )


def table_load_balance(n_ops: int):
    from benchmarks.paper_tables import load_balance_effect

    r = load_balance_effect(n_ops)
    _emit("load_balance/zipf1.2", r["max_load_before"],
          f"imb_before={r['imbalance_before']:.2f};imb_after={r['imbalance_after']:.2f};"
          f"migrations={r['migrations']}")


def table_hierarchy(n_ops: int):
    from benchmarks.paper_tables import hierarchy_stats

    r = hierarchy_stats(n_ops)
    _emit("hierarchy/2pods", 0.0,
          f"pod_crossing={r['pod_crossing_fraction']:.3f};"
          f"agreement={r['pod_table_agreement']:.3f}")


def table_kernels():
    from benchmarks.kernel_bench import (
        bench_range_match, bench_range_match_apply, bench_decode_attn, bench_ssd,
    )

    for name, us, derived in (bench_range_match() + bench_range_match_apply()
                              + bench_decode_attn() + bench_ssd()):
        _emit(name, us, derived)


def table_engine(n_ops: int, quick: bool):
    from benchmarks.coordination_bench import bench_engine

    rows, wall = bench_engine(
        n_ops, include_reference=not quick, include_1m=not quick)
    for name, us, derived in rows:
        _emit(name, us, derived)
    return wall


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="smaller op counts")
    ap.add_argument("--engine", choices=("reference", "vectorized"),
                    default="vectorized",
                    help="DES implementation for the coordination benchmarks")
    ap.add_argument("--n-ops", type=int, default=None,
                    help="ops per workload (default: 2048 quick, 8192 full)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write rows + engine wall-clock to PATH")
    args = ap.parse_args()
    if args.n_ops is not None and args.n_ops < 1:
        ap.error("--n-ops must be >= 1")
    n = args.n_ops if args.n_ops is not None else (2048 if args.quick else 8192)

    t0 = time.perf_counter()
    print("name,us_per_call,derived")
    table_fig13a(n, args.engine)
    table_fig13bc(n, args.engine)
    tables_1_2(n, args.engine)
    table_load_balance(n)
    table_hierarchy(n)
    table_kernels()
    wall = table_engine(n, args.quick)
    total = time.perf_counter() - t0

    if args.json:
        payload = {
            "meta": {
                "n_ops": n,
                "engine": args.engine,
                "quick": args.quick,
                "backends": list(C.des.available_backends()),
                "suite_wall_clock_s": total,
            },
            "engine_wall_clock": wall,
            "rows": [
                {"name": name, "us_per_call": us, "derived": derived}
                for name, us, derived in _ROWS
            ],
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {len(_ROWS)} rows -> {args.json}", flush=True)


if __name__ == "__main__":
    main()
